"""Vectorized codec vs retained reference implementation (hypothesis-free).

``tests/test_codec.py`` skips entirely when ``hypothesis`` is missing, so
the old-vs-new equivalence property this PR rests on lives here, driven by
seeded ``default_rng`` fuzz instead: the chunked ``BitWriter``/``BitReader``
and vectorized ``compress_words``/``decompress_words`` must be bit-identical
to the seed's bignum reference (kept as ``Reference*`` / ``*_ref``) on every
paper data type, and ``compressed_cost_bits`` must equal the written length.
"""
import numpy as np
import pytest

from repro.core import compression as comp
from repro.core import mars, stencil

ALL_NBITS = sorted({nb for nb, _ in comp.DATA_TYPES.values()})


def _random_words(rng, n, nbits):
    """Mix of smooth (small-delta) and uniform words — exercises all k."""
    mask = (1 << nbits) - 1
    smooth = np.cumsum(rng.integers(-3, 4, size=n)).astype(object)
    uniform = rng.integers(0, 1 << min(nbits, 63), size=n).astype(object)
    pick = rng.integers(0, 2, size=n).astype(bool)
    w = np.where(pick, smooth, uniform)
    return np.array([int(x) & mask for x in w], dtype=np.uint64)


@pytest.mark.parametrize("nbits", ALL_NBITS)
def test_fast_codec_bit_identical_to_reference(nbits):
    rng = np.random.default_rng(nbits)
    for n in (1, 2, 7, 257):
        words = _random_words(rng, n, nbits)

        ref_w = comp.ReferenceBitWriter()
        comp.compress_words_ref(words, nbits, ref_w)
        fast_w = comp.BitWriter()
        comp.compress_words(words, nbits, fast_w)

        assert fast_w.bit_length == ref_w.bit_length
        np.testing.assert_array_equal(fast_w.to_words(32),
                                      ref_w.to_words(32))
        assert comp.compressed_cost_bits(words, nbits) == fast_w.bit_length

        # cross-decode: each reader over each writer's stream
        bits = fast_w.bit_length
        for stream in (fast_w.to_words(32), ref_w.to_words(32)):
            out_fast = comp.decompress_words(
                comp.BitReader(stream, bits, 32), n, nbits)
            out_ref = comp.decompress_words_ref(
                comp.ReferenceBitReader(stream, bits, 32), n, nbits)
            np.testing.assert_array_equal(out_fast, words)
            np.testing.assert_array_equal(out_ref, words)


@pytest.mark.parametrize("dtype", sorted(comp.DATA_TYPES))
def test_mars_stream_roundtrip_fuzz(dtype):
    nbits = comp.DATA_TYPES[dtype][0]
    rng = np.random.default_rng(hash(dtype) % 2**32)
    for trial in range(5):
        shapes = [rng.integers(1, 40) for _ in range(rng.integers(1, 7))]
        mars_data = [_random_words(rng, int(s), nbits) for s in shapes]
        stream = comp.compress_mars_stream(mars_data, nbits)
        assert len(stream.markers) == len(mars_data)
        for k, arr in enumerate(mars_data):
            np.testing.assert_array_equal(
                comp.decompress_mars(stream, k), arr)


def test_mars_stream_empty_and_single_word():
    for nbits in (12, 64):
        stream = comp.compress_mars_stream([], nbits)
        assert stream.total_bits == 0 and stream.markers == []
        one = comp.compress_mars_stream([np.array([5], np.uint64)], nbits)
        np.testing.assert_array_equal(comp.decompress_mars(one, 0), [5])
        # w0 raw + nothing else: exactly nbits on the wire
        assert one.total_bits == nbits


def test_compressed_cost_bits_signed_wrap_at_64():
    """nbits=64 deltas wrap mod 2^64; the cost model must agree with the
    writer (the seed overflowed int64 here before `_bit_length_u64`)."""
    words = np.array([0, (1 << 64) - 1, 1, 1 << 63], dtype=np.uint64)
    w = comp.BitWriter()
    comp.compress_words(words, 64, w)
    assert comp.compressed_cost_bits(words, 64) == w.bit_length
    out = comp.decompress_words(
        comp.BitReader(w.to_words(32), w.bit_length, 32), len(words), 64)
    np.testing.assert_array_equal(out, words)


def test_reader_seek_bounds():
    words = np.array([1, 2, 3], dtype=np.uint64)
    for cls in (comp.BitReader, comp.ReferenceBitReader):
        r = cls(words, 96, 32)
        r.seek(0)
        r.seek(96)
        with pytest.raises(ValueError):
            r.seek(97)
        with pytest.raises(ValueError):
            r.seek(-1)
        r.seek(90)
        with pytest.raises(EOFError):
            r.read(7)


def test_decompress_mars_corruption_errors():
    nbits = 18
    data = [np.arange(10, dtype=np.uint64), np.arange(5, dtype=np.uint64)]
    stream = comp.compress_mars_stream(data, nbits)

    with pytest.raises(IndexError, match="out of range"):
        comp.decompress_mars(stream, 2)
    with pytest.raises(IndexError, match="out of range"):
        comp.decompress_mars(stream, -1)

    import dataclasses
    bad_marker = dataclasses.replace(
        stream, markers=[comp.Marker(coarse=10**6, fine=0),
                         stream.markers[1]])
    with pytest.raises(ValueError, match="corrupt marker"):
        comp.decompress_mars(bad_marker, 0)

    bad_count = dataclasses.replace(stream, counts=[-1, 5])
    with pytest.raises(ValueError, match="corrupt count"):
        comp.decompress_mars(bad_count, 0)

    # count overrunning the stream must fail loudly, not decode garbage
    overrun = dataclasses.replace(stream, counts=[10**4, 5])
    with pytest.raises(ValueError, match="corrupt stream decoding MARS 0"):
        comp.decompress_mars(overrun, 0)

    # flipped bits in a length field (k >= nbits) are detected
    garbage = dataclasses.replace(
        stream, words=np.full_like(stream.words, (1 << 32) - 1))
    with pytest.raises(ValueError, match="corrupt stream decoding MARS"):
        comp.decompress_mars(garbage, 0)


@pytest.mark.parametrize("name,ts", [
    ("jacobi-1d", (6, 6)), ("jacobi-1d", (64, 64)),
    ("jacobi-2d", (4, 5, 7)), ("seidel-2d", (4, 10, 10))])
def test_translated_analysis_matches_direct(name, ts):
    """`analyze(spec, tile)` now translates one cached canonical analysis;
    it must equal the direct per-tile computation everywhere."""
    spec = stencil.SPECS[name](ts)
    rng = np.random.default_rng(7)
    tiles = [tuple(int(x) for x in rng.integers(3, 50, spec.ndim))
             for _ in range(3)]
    for tile in tiles:
        fast = mars.analyze(spec, tile)
        direct = mars._analyze_at(spec, tile)
        assert fast.tile_points == direct.tile_points
        assert len(fast.out_mars) == len(direct.out_mars)
        for mf, md in zip(fast.out_mars, direct.out_mars):
            np.testing.assert_array_equal(mf.points, md.points)
        assert set(fast.consumed) == set(direct.consumed)
        for off in direct.consumed:
            assert tuple(fast.consumed[off]) == tuple(direct.consumed[off])
