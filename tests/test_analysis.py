"""repro.analysis: rule firing on injected violations + clean-tree green.

The four violation fixtures the acceptance criteria name — redundant
transfer, strided access, obs-call-under-jit, invalid layout permutation
— each must produce a nonzero outcome, and the real tree must be clean.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import access, layout_invariants, obs_discipline, runner
from repro.analysis.findings import (Finding, load_baseline, sort_findings,
                                     split_by_baseline, write_baseline)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cli(args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          capture_output=True, text=True, env=env, cwd=cwd)


# ---------------------------------------------------------------------------
# findings model
# ---------------------------------------------------------------------------

def test_finding_fingerprint_stable_across_line_drift():
    a = Finding("OBS201", "error", "repro/x.py:10", "msg")
    b = Finding("OBS201", "error", "repro/x.py:99", "msg")
    c = Finding("OBS201", "error", "repro/y.py:10", "msg")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != c.fingerprint


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        Finding("X", "fatal", "loc", "msg")


def test_baseline_roundtrip(tmp_path):
    f1 = Finding("ACC101", "error", "k/a", "m1")
    f2 = Finding("ACC102", "warning", "k/b", "m2")
    path = str(tmp_path / "baseline.json")
    write_baseline([f1], path)
    base = load_baseline(path)
    new, suppressed = split_by_baseline([f1, f2], base)
    assert suppressed == [f1] and new == [f2]
    assert sort_findings([f2, f1])[0] is f1  # error sorts before warning


# ---------------------------------------------------------------------------
# injected-violation fixtures (one per acceptance criterion)
# ---------------------------------------------------------------------------

def test_redundant_transfer_fixture_fires():
    case = access.KernelCase("fx/redundant", runner.REDUNDANT_HLO,
                             read_bytes=4096, write_bytes=4096)
    fs = access.check_redundancy(case)
    assert any(f.rule == "ACC101" and f.severity == "error" for f in fs)
    # honest charge: clean
    ok = access.KernelCase("fx/ok", runner.REDUNDANT_HLO,
                           read_bytes=4096, write_bytes=8192)
    assert access.check_redundancy(ok) == []


def test_strided_access_fixture_fires():
    case = access.KernelCase("fx/strided", runner.STRIDED_HLO,
                             read_bytes=16384, write_bytes=8192)
    fs = access.check_contiguity(case)
    assert any(f.rule == "ACC102" for f in fs)
    assert "stride 2" in fs[0].message
    assert "cycles" in fs[0].message  # burst-model quote present


def test_contiguity_ignores_onchip_temporaries():
    # the strided slice reads a constant, not a parameter-derived value
    hlo = runner.STRIDED_HLO.replace(
        "slice(f32[64,64]{1,0} %p0)", "slice(f32[64,64]{1,0} %cst)")
    case = access.KernelCase("fx/onchip", hlo, 16384, 8192)
    assert access.check_contiguity(case) == []


def test_misaligned_pack_fixture_fires():
    case = access.KernelCase("fx/misaligned", runner.REDUNDANT_HLO,
                             read_bytes=8192, write_bytes=8192,
                             pack_bits=5, pack_block=48)
    fs = access.check_pack_alignment(case)
    assert sum(f.rule == "ACC103" for f in fs) == 2  # width + block
    ok = access.KernelCase("fx/aligned", runner.REDUNDANT_HLO,
                           8192, 8192, pack_bits=4, pack_block=32)
    assert access.check_pack_alignment(ok) == []


def test_obs_under_jit_fixture_fires():
    nodes = obs_discipline.scan_source(runner.OBS_UNDER_JIT_SRC, "fx.py")
    fs = obs_discipline.run_pass(nodes)
    assert len(fs) == 1
    assert fs[0].rule == "OBS201" and fs[0].severity == "error"
    assert "counter_inc" in fs[0].message and "fx.py::kernel" in fs[0].message


def test_obs_host_side_recording_is_clean():
    src = textwrap.dedent("""\
        import jax
        from repro.obs import instrument as obs

        @jax.jit
        def kernel(x):
            return x * 2

        def host(x):
            with obs.span("host/step"):
                obs.counter_inc("host/calls", 1)
                return kernel(x)
    """)
    assert obs_discipline.run_pass(obs_discipline.scan_source(src, "h.py")) \
        == []


def test_obs_pass_catches_scan_body_and_lambda():
    src = textwrap.dedent("""\
        import jax
        from repro.obs import instrument as obs

        def step(carry, x):
            obs.gauge_set("bad/inner", 1.0)
            return carry, x

        def run(xs):
            return jax.lax.scan(step, 0, xs)
    """)
    fs = obs_discipline.run_pass(obs_discipline.scan_source(src, "s.py"))
    assert len(fs) == 1 and "passed to jax.lax.scan" in fs[0].message


def test_invalid_layout_permutation_fixture_fires():
    import dataclasses

    from repro.core import layout, mars, stencil

    a = mars.analyze(stencil.SPECS["jacobi-1d"]((6, 6)))
    good = layout.layout_for_analysis(a)
    bad = dataclasses.replace(
        good, order=tuple([good.order[1]] + list(good.order[1:])))
    fs = layout_invariants.check_layout("jacobi-1d", (6, 6), a, result=bad)
    assert any(f.rule == "LAY301" for f in fs)

    lied = dataclasses.replace(good, read_bursts=good.read_bursts + 1)
    fs = layout_invariants.check_layout("jacobi-1d", (6, 6), a, result=lied)
    assert any(f.rule == "LAY302" for f in fs)


# ---------------------------------------------------------------------------
# clean-tree runs (host-only passes: fast, no jax lowering)
# ---------------------------------------------------------------------------

def test_layout_invariants_clean_on_zoo():
    assert layout_invariants.run_pass() == []


def test_obs_discipline_clean_on_tree():
    fs = obs_discipline.analyze_tree(os.path.join(REPO, "src", "repro"))
    assert fs == []


def test_data_types_table_clean():
    assert access.check_data_types() == []


def test_selftest_all_rules_fire():
    st = runner.selftest()
    assert st["ok"], st["fired"]
    assert set(st["fired"]) >= {"redundant-transfer", "strided-access",
                                "misaligned-pack", "obs-under-jit",
                                "invalid-permutation", "burst-miscount"}


# ---------------------------------------------------------------------------
# CLI: exit codes and baseline workflow (subprocess, host-only passes)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_clean_tree_exits_zero(tmp_path):
    out = str(tmp_path / "report.json")
    r = _cli(["--no-access", "--json", out])
    assert r.returncode == 0, r.stdout + r.stderr
    with open(out) as f:
        report = json.load(f)
    assert report["n_new"] == 0


@pytest.mark.slow
def test_cli_violation_exits_nonzero_until_suppressed(tmp_path):
    badroot = tmp_path / "badpkg"
    badroot.mkdir()
    (badroot / "bad.py").write_text(runner.OBS_UNDER_JIT_SRC)
    r = _cli(["--no-access", "--root", str(badroot)])
    assert r.returncode == 1
    assert "OBS201" in r.stdout

    # suppression workflow: record the baseline, rerun -> green
    base = str(tmp_path / "baseline.json")
    r = _cli(["--no-access", "--root", str(badroot),
              "--baseline", base, "--write-baseline"])
    assert r.returncode == 0
    r = _cli(["--no-access", "--root", str(badroot), "--baseline", base])
    assert r.returncode == 0
    assert "suppressed OBS201" in r.stdout


@pytest.mark.slow
def test_cli_selftest_exits_zero():
    r = _cli(["--selftest"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "selftest: ok" in r.stdout
