"""Fault-tolerant training loop: convergence, restart, determinism."""
import shutil

import numpy as np
import pytest

from repro.configs import base
from repro.train.loop import LoopConfig, train


@pytest.fixture()
def cfg_rc():
    cfg = base.load_smoke("tinyllama-1.1b")
    rc = base.RunConfig(seq_len=64, global_batch=8, kind="train", remat=False,
                        q_block=32, kv_block=32, lr=1e-3)
    return cfg, rc


def test_loss_decreases(cfg_rc, tmp_path):
    cfg, rc = cfg_rc
    hist = train(cfg, rc, LoopConfig(total_steps=30, ckpt_every=10,
                                     ckpt_dir=str(tmp_path)), log_every=0)
    assert hist["loss"][-1] < hist["loss"][0] - 0.3


def test_failure_recovery_resumes_batch_sequence(cfg_rc, tmp_path):
    cfg, rc = cfg_rc
    ref_dir, failed_dir = str(tmp_path / "a"), str(tmp_path / "b")
    ref = train(cfg, rc, LoopConfig(total_steps=25, ckpt_every=5,
                                    ckpt_dir=ref_dir), log_every=0)
    fired = []

    def hook(step):
        if step == 13 and not fired:
            fired.append(1)
            raise RuntimeError("injected node failure")

    got = train(cfg, rc, LoopConfig(total_steps=25, ckpt_every=5,
                                    ckpt_dir=failed_dir),
                failure_hook=hook, log_every=0)
    assert got["restarts"] == 1
    # post-recovery losses match the uninterrupted run (deterministic
    # pipeline + checkpoint restore = bit-identical batch sequence)
    assert np.allclose(ref["loss"][-5:], got["loss"][-5:], atol=1e-5)


def test_gives_up_after_max_restarts(cfg_rc, tmp_path):
    cfg, rc = cfg_rc

    def hook(step):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        train(cfg, rc, LoopConfig(total_steps=10, ckpt_every=5,
                                  ckpt_dir=str(tmp_path), max_restarts=2),
              failure_hook=hook, log_every=0)
