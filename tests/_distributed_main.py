"""Subprocess body for tests/test_distributed.py — runs with 8 host devices.

Invoked as:  python tests/_distributed_main.py <scenario>

Scenarios:
  compressed_grads  — multi-pod mesh, compressed vs plain cross-pod gradient
                      exchange: losses must track closely (error feedback)
  remesh            — train on mesh A, checkpoint, restore on mesh B
                      (elastic re-mesh), losses must continue identically
  dist_equivalence  — sharded (2,2) mesh train step == single-device step
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import base                       # noqa: E402
from repro.data.pipeline import SyntheticPipeline, device_batch  # noqa: E402
from repro.distributed import sharding as shd        # noqa: E402
from repro.models import model_zoo                   # noqa: E402
from repro.train import step as ts                   # noqa: E402
from repro.train.loop import LoopConfig, train       # noqa: E402


def _run_steps(cfg, rc, mesh, n_steps, seed=0):
    rules = shd.Rules(mesh=mesh, seq_shard=rc.seq_shard, fsdp=rc.fsdp)
    with shd.use_rules(rules):
        api = model_zoo.get_api(cfg, rc)
        fn = jax.jit(ts.make_train_step(api, cfg, rc, mesh))
        state = ts.init_state(api, rc, jax.random.PRNGKey(seed), mesh)
        pipe = SyntheticPipeline(cfg, rc, seed=3)
        losses = []
        for _ in range(n_steps):
            batch = device_batch(pipe.next(), cfg, rc)
            state, m = fn(state, batch)
            losses.append(float(jax.device_get(m["loss"])))
    return losses, state


def scenario_compressed_grads():
    cfg = base.load_smoke("tinyllama-1.1b")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rc0 = base.RunConfig(seq_len=64, global_batch=8, kind="train",
                         remat=False, q_block=32, kv_block=32, lr=1e-3,
                         grad_compress_bits=0)
    rc8 = base.RunConfig(seq_len=64, global_batch=8, kind="train",
                         remat=False, q_block=32, kv_block=32, lr=1e-3,
                         grad_compress_bits=8)
    plain, _ = _run_steps(cfg, rc0, mesh, 20)
    comp, _ = _run_steps(cfg, rc8, mesh, 20)
    print("plain last:", plain[-1], "compressed last:", comp[-1])
    assert comp[-1] < plain[0] - 0.2, "compressed run failed to learn"
    assert abs(comp[-1] - plain[-1]) < 0.35, (comp[-1], plain[-1])
    # 16-bit compression must track essentially exactly
    rc16 = base.RunConfig(seq_len=64, global_batch=8, kind="train",
                          remat=False, q_block=32, kv_block=32, lr=1e-3,
                          grad_compress_bits=16)
    comp16, _ = _run_steps(cfg, rc16, mesh, 20)
    assert abs(comp16[-1] - plain[-1]) < 0.1, (comp16[-1], plain[-1])
    print("OK compressed_grads")


def scenario_remesh():
    cfg = base.load_smoke("tinyllama-1.1b")
    rc = base.RunConfig(seq_len=64, global_batch=8, kind="train",
                        remat=False, q_block=32, kv_block=32, lr=1e-3)
    with tempfile.TemporaryDirectory() as d:
        loop = LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=d)
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        h1 = train(cfg, rc, loop, mesh=mesh_a, log_every=0)
        # resume the SAME run on a different device organization
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        loop2 = LoopConfig(total_steps=20, ckpt_every=5, ckpt_dir=d)
        h2 = train(cfg, rc, loop2, mesh=mesh_b, log_every=0)
        # reference: uninterrupted single-mesh run
        with tempfile.TemporaryDirectory() as d2:
            ref = train(cfg, rc, LoopConfig(total_steps=20, ckpt_every=5,
                                            ckpt_dir=d2),
                        mesh=mesh_a, log_every=0)
        got, want = h2["loss"][-3:], ref["loss"][-3:]
        print("remesh tail:", got, "ref tail:", want)
        assert np.allclose(got, want, atol=5e-3), (got, want)
    print("OK remesh")


def scenario_dist_equivalence():
    cfg = base.load_smoke("yi-9b")
    rc = base.RunConfig(seq_len=64, global_batch=8, kind="train",
                        remat=False, q_block=32, kv_block=32, lr=1e-3)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    dist, _ = _run_steps(cfg, rc, mesh, 5)
    single, _ = _run_steps(cfg, rc, None, 5)
    print("dist:", dist, "single:", single)
    assert np.allclose(dist, single, atol=5e-3), (dist, single)
    print("OK dist_equivalence")


if __name__ == "__main__":
    {
        "compressed_grads": scenario_compressed_grads,
        "remesh": scenario_remesh,
        "dist_equivalence": scenario_dist_equivalence,
    }[sys.argv[1]]()
