"""Synthetic data pipeline: determinism, restore, learnable structure."""
import numpy as np

from repro.configs import base
from repro.data.pipeline import SyntheticPipeline


def _mk(arch="tinyllama-1.1b", **kw):
    cfg = base.load_smoke(arch)
    rc = base.RunConfig(seq_len=32, global_batch=4, kind="train", **kw)
    return cfg, rc


def test_deterministic_across_instances():
    cfg, rc = _mk()
    a = SyntheticPipeline(cfg, rc, seed=7)
    b = SyntheticPipeline(cfg, rc, seed=7)
    for _ in range(3):
        ba, bb = a.next(), b.next()
        assert np.array_equal(ba["tokens"], bb["tokens"])
        assert np.array_equal(ba["labels"], bb["labels"])


def test_restore_resumes_exact_stream():
    cfg, rc = _mk()
    a = SyntheticPipeline(cfg, rc, seed=1)
    for _ in range(5):
        a.next()
    state = a.state()
    want = a.next()
    b = SyntheticPipeline(cfg, rc, seed=99)  # wrong seed, fixed by restore
    b.restore(state)
    got = b.next()
    assert np.array_equal(want["tokens"], got["tokens"])


def test_labels_are_shifted_tokens():
    cfg, rc = _mk()
    p = SyntheticPipeline(cfg, rc)
    b = p.next()
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab


def test_modality_stubs():
    cfg, rc = _mk("whisper-tiny")
    b = SyntheticPipeline(cfg, rc).next()
    assert b["frames"].shape == (4, cfg.enc_seq, cfg.d_model)
    cfg, rc = _mk("internvl2-76b")
    b = SyntheticPipeline(cfg, rc).next()
    assert b["vis_embeds"].shape == (4, cfg.n_vis_tokens, cfg.d_model)
    assert b["tokens"].shape == (4, 32 - cfg.n_vis_tokens)
