"""Compression codecs: faithful §2.5 stream + TPU block codec."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import blockcodec as bc
from repro.core import compression as comp
from repro.core import packing


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([12, 18, 24, 28, 32, 64]),
       st.lists(st.integers(0, 2**31), min_size=1, max_size=120),
       st.booleans())
def test_faithful_roundtrip_and_cost(nbits, vals, smooth):
    mask = (1 << nbits) - 1
    words = np.array(vals, dtype=np.uint64)
    if smooth:
        words = np.cumsum(words % 7, dtype=np.uint64)
    words &= np.uint64(mask)
    w = comp.BitWriter()
    comp.compress_words(words, nbits, w)
    r = comp.BitReader(w.to_words(32), w.bit_length, 32)
    out = comp.decompress_words(r, len(words), nbits)
    assert np.array_equal(out, words)
    # vectorized size model is bit-exact vs the real stream
    assert comp.compressed_cost_bits(words, nbits) == w.bit_length


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 40), min_size=1, max_size=6))
def test_markers_seek_any_mars(sizes):
    rng = np.random.default_rng(0)
    arrs = [rng.integers(0, 1 << 18, size=k, dtype=np.uint64) for k in sizes]
    s = comp.compress_mars_stream(arrs, 18)
    assert len(s.markers) == len(arrs)
    for i in np.random.default_rng(1).permutation(len(arrs)):
        assert np.array_equal(comp.decompress_mars(s, int(i)), arrs[int(i)])


def test_smooth_data_compresses():
    """Jacobi-like smooth data must beat the padded baseline (Fig. 11)."""
    x = np.cumsum(np.random.default_rng(0).uniform(-1e-4, 1e-4, 50_000)) + 0.5
    words = comp.quantize_fixed(x, 18)
    bits = comp.compressed_cost_bits(words, 18)
    r = packing.compression_ratios(len(x), 18, bits)
    assert r.ratio_with_padding > 2.0
    assert r.true_ratio > 1.1


def test_fixed_point_quantization_error():
    x = np.random.default_rng(0).uniform(-1, 1, 1000)
    w = comp.quantize_fixed(x, 18)
    y = comp.dequantize_fixed(w, 18)
    assert np.abs(x - y).max() <= 2 ** -(18 - 2) + 1e-12


# --- block codec (TPU form) -------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from([1, 3, 7, 8, 13, 17, 32]), st.integers(1, 5))
def test_bitplane_roundtrip(b, nrows):
    rng = np.random.default_rng(b)
    lo = -(1 << (b - 1)) if b < 32 else -(2**31)
    hi = (1 << (b - 1)) - 1 if b < 32 else 2**31 - 1
    v = rng.integers(lo, hi + 1, size=(nrows, 2, 32)).astype(np.int32)
    planes = bc.bitplane_pack(jnp.asarray(v), b)
    out = bc.bitplane_unpack(planes, b)
    assert np.array_equal(np.asarray(out), v)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([4, 6, 8, 12]), st.booleans())
def test_block_codec_error_bound(bits, delta):
    rng = np.random.default_rng(bits)
    x = rng.standard_normal(4 * 256).astype(np.float32)
    cfg = bc.BlockCodecConfig(bits=bits, block=256, delta=delta)
    planes, scale = bc.compress(jnp.asarray(x), cfg)
    y = np.asarray(bc.decompress(planes, scale, cfg)).reshape(-1)
    qbits = bits - 1 if delta else bits
    step = np.abs(x).reshape(-1, 256).max(axis=1) / (2 ** (qbits - 1) - 1)
    err = np.abs(x - y).reshape(-1, 256).max(axis=1)
    assert (err <= step + 1e-6).all()


def test_block_codec_wire_size():
    cfg = bc.BlockCodecConfig(bits=8, block=256, delta=False)
    assert bc.compressed_bytes(1024, cfg) == 4 * (256 // 32) * 8 * 4 + 4 * 4
    # ~4x smaller than f32
    assert bc.compressed_bytes(1024, cfg) < 1024 * 4 / 3.8


def test_varwidth_encoder_adapts():
    rng = np.random.default_rng(0)
    smooth = np.cumsum(rng.integers(-2, 3, 4096)).astype(np.int32)
    rough = rng.integers(-2**20, 2**20, 4096).astype(np.int32)
    bs, ws = bc.encode_varwidth(smooth, 256)
    br, wr = bc.encode_varwidth(rough, 256)
    assert bs < br
    assert ws.max() <= wr.max()
