"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("bits", [4, 6, 8, 12, 16])
@pytest.mark.parametrize("n,block", [(8, 128), (16, 256), (32, 512)])
def test_bitplane_pack_unpack_sweep(bits, n, block):
    rng = np.random.default_rng(bits * n)
    lim = max(1 << (bits - 2), 1)
    d = rng.integers(-lim // 2 - 1, lim // 2 + 1, size=(n, block)).astype(np.int32)
    q = np.cumsum(d, axis=1, dtype=np.int32)
    qj = jnp.asarray(q)
    p_ref = ref.pack_ref(qj, bits)
    p_int = ops.pack_codes(qj, bits, use_pallas="interpret")
    assert np.array_equal(np.asarray(p_ref), np.asarray(p_int))
    u_int = ops.unpack_codes(p_int, bits, block, use_pallas="interpret")
    assert np.array_equal(np.asarray(u_int), q)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("rows,d", [(8, 128), (32, 128), (16, 256)])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_kv_quant_sweep(bits, rows, d, dtype):
    rng = np.random.default_rng(rows)
    x = rng.standard_normal((rows, d)).astype(np.float32)
    xj = jnp.asarray(x, dtype=jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)
    c_ref, s_ref = ref.kv_quant_ref(xj, bits)
    c_int, s_int = ops.kv_quant(xj, bits, use_pallas="interpret")
    assert np.allclose(np.asarray(s_ref), np.asarray(s_int), rtol=1e-6)
    # compare through dequantization: 1-ulp scale differences may flip
    # round-half ties, so allow up to one quantization step on <1% of entries
    y_ref = np.asarray(ref.kv_dequant_ref(c_ref, s_ref, bits))
    y_int = np.asarray(ops.kv_dequant(c_int, s_int, bits, use_pallas="interpret"))
    step = np.asarray(s_ref)  # (rows, 1): one code step in value space
    d = np.abs(y_ref - y_int)
    assert (d <= step + 1e-6).all(), d.max()
    assert (d > 1e-6 * np.maximum(step, 1)).mean() < 0.01
    # quantization error bound vs the true input
    xf = np.asarray(xj, dtype=np.float32)
    qstep = np.abs(xf).max(axis=1) / (2 ** (bits - 1) - 1)
    assert (np.abs(y_ref - xf).max(axis=1) <= qstep + 1e-5).all()


@pytest.mark.parametrize("t_steps,width,n", [
    (4, 256, 1024), (16, 512, 2048), (63, 128, 1024), (8, 1024, 4096)])
def test_jacobi_chunked_sweep(t_steps, width, n):
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n).astype(np.float32)
    y_ref = np.asarray(ref.jacobi_chunked_ref(jnp.asarray(x), t_steps))
    y_int = np.asarray(ops.jacobi1d_tiled(jnp.asarray(x), t_steps, width=width,
                                          use_pallas="interpret"))
    assert np.abs(y_ref - y_int).max() < 1e-5


def test_ops_ref_fallback_matches_interpret():
    rng = np.random.default_rng(0)
    q = np.cumsum(rng.integers(-3, 4, size=(8, 256)), axis=1).astype(np.int32)
    a = ops.pack_codes(jnp.asarray(q), 6, use_pallas="ref")
    b = ops.pack_codes(jnp.asarray(q), 6, use_pallas="interpret")
    assert np.array_equal(np.asarray(a), np.asarray(b))
