"""Logical sharding rules: divisibility fallbacks and spec resolution."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import abstract_mesh


@pytest.fixture()
def mesh_rules():
    # 1 real device: an abstract mesh suffices for rule resolution
    mesh = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    return shd.Rules(mesh=mesh, seq_shard=True, fsdp=True)


def test_batch_axis_composition(mesh_rules):
    assert mesh_rules.resolve("batch", 8) == ("pod", "data")
    assert mesh_rules.resolve("batch", 3) is None      # not divisible by 4


def test_divisibility_fallbacks(mesh_rules):
    assert mesh_rules.resolve("heads", 6) == "model"
    assert mesh_rules.resolve("heads", 7) is None      # whisper-style 6h/16tp
    assert mesh_rules.resolve("vocab", 32001) is None  # hymba odd vocab
    assert mesh_rules.resolve("ff", 256) == "model"


def test_seq_and_fsdp_toggles():
    mesh = abstract_mesh((2, 2), ("data", "model"))
    r = shd.Rules(mesh=mesh, seq_shard=False, fsdp=False)
    assert r.resolve("seq", 128) is None
    assert r.resolve("fsdp", 128) is None
    r2 = shd.Rules(mesh=mesh, seq_shard=True, fsdp=True)
    assert r2.resolve("seq", 128) == "model"
    assert r2.resolve("fsdp", 128) == "data"


def test_exclude_manual_axis(mesh_rules):
    import dataclasses
    r = dataclasses.replace(mesh_rules, exclude=frozenset({"pod"}))
    assert r.resolve("batch", 8) == ("data",)


def test_spec_builds_partition_spec(mesh_rules):
    spec = mesh_rules.spec((8, 64, 128), ("batch", "seq", None))
    assert spec == P(("pod", "data"), "model", None)


def test_no_rules_is_noop():
    shd.set_rules(None)
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shd.act(x, "batch", None) is x
