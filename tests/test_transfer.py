"""Transfer-cycle model: burst accounting matches the layout results."""
import numpy as np
import pytest

from repro.core import layout, mars, stencil, transfer


@pytest.fixture(scope="module")
def jacobi_setup():
    spec = stencil.SPECS["jacobi-1d"]((64, 64))
    a = mars.analyze(spec)
    lr = layout.layout_for_analysis(a)
    rep = tuple(int(x) for x in spec.tile_of(np.array([[150, 2000]]))[0])
    m = transfer.TileIOModel(spec, a, lr, rep_tile=rep)
    init = np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, 4000)) + 1.0
    hist = stencil.jacobi1d_reference(init, 300)
    return m, hist


def test_transaction_counts_match_layout(jacobi_setup):
    m, hist = jacobi_setup
    io = m.tile_io("fixed18", "mars")
    assert io.read_transactions == 3 and io.write_transactions == 1


def test_mode_ordering(jacobi_setup):
    """pack < padded; compression < pack (smooth data); minimal is worst."""
    m, hist = jacobi_setup
    cyc = {mode: m.tile_io("fixed18", mode, hist=hist).total_cycles
           for mode in transfer.MODES}
    assert cyc["mars_pack"] < cyc["mars"]
    assert cyc["mars_comp"] < cyc["mars_pack"]
    assert cyc["minimal"] > cyc["mars"]
    assert cyc["mars"] <= cyc["bbox"] + 8  # 1D data: bbox already bursts


def test_float_dtypes_account_padded_width(jacobi_setup):
    m, hist = jacobi_setup
    io18 = m.tile_io("fixed18", "mars")
    io32 = m.tile_io("float", "mars")
    assert io18.read_bits == io32.read_bits  # both pad to 32-bit words
    io18p = m.tile_io("fixed18", "mars_pack")
    assert io18p.read_bits < io32.read_bits


def test_burst_init_cost_dominates_minimal():
    model = transfer.TransferModel(bus_bits=64, burst_init=8)
    assert model.transaction_cycles(64) == 9
    assert model.transaction_cycles(64 * 10) == 18
    # max beats splitting
    big = model.transaction_cycles(64 * 1000)
    assert big == 8 * 4 + 1000


def test_2d_contiguity_gains():
    """jacobi-2d: MARS layout beats bbox/minimal on transactions (paper §5.2.3:
    gains are due to contiguity in higher dims)."""
    spec = stencil.SPECS["jacobi-2d"]((4, 5, 7))
    a = mars.analyze(spec)
    lr = layout.layout_for_analysis(a)
    m = transfer.TileIOModel(spec, a, lr)
    io_mars = m.tile_io("float", "mars")
    io_min = m.tile_io("float", "minimal")
    assert io_mars.read_transactions == 10
    assert io_min.read_transactions > 2 * io_mars.read_transactions
    assert io_mars.total_cycles < io_min.total_cycles
