"""Transfer-cycle model: burst accounting matches the layout results."""
import numpy as np
import pytest

from repro.core import layout, mars, stencil, transfer


@pytest.fixture(scope="module")
def jacobi_setup():
    spec = stencil.SPECS["jacobi-1d"]((64, 64))
    a = mars.analyze(spec)
    lr = layout.layout_for_analysis(a)
    rep = tuple(int(x) for x in spec.tile_of(np.array([[150, 2000]]))[0])
    m = transfer.TileIOModel(spec, a, lr, rep_tile=rep)
    init = np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, 4000)) + 1.0
    hist = stencil.jacobi1d_reference(init, 300)
    return m, hist


def test_transaction_counts_match_layout(jacobi_setup):
    m, hist = jacobi_setup
    io = m.tile_io("fixed18", "mars")
    assert io.read_transactions == 3 and io.write_transactions == 1


def test_mode_ordering(jacobi_setup):
    """pack < padded; compression < pack (smooth data); minimal is worst."""
    m, hist = jacobi_setup
    cyc = {mode: m.tile_io("fixed18", mode, hist=hist).total_cycles
           for mode in transfer.MODES}
    assert cyc["mars_pack"] < cyc["mars"]
    assert cyc["mars_comp"] < cyc["mars_pack"]
    assert cyc["minimal"] > cyc["mars"]
    assert cyc["mars"] <= cyc["bbox"] + 8  # 1D data: bbox already bursts


def test_float_dtypes_account_padded_width(jacobi_setup):
    m, hist = jacobi_setup
    io18 = m.tile_io("fixed18", "mars")
    io32 = m.tile_io("float", "mars")
    assert io18.read_bits == io32.read_bits  # both pad to 32-bit words
    io18p = m.tile_io("fixed18", "mars_pack")
    assert io18p.read_bits < io32.read_bits


def test_runs_coalesce_contiguous_cells_within_a_row():
    """Regression: the row key must be the primary sort key.

    The seed sorted with the innermost coordinate primary, so contiguous
    cells of one row never coalesced (``[1, 1, 2]`` here) and the minimal
    baseline was inflated.
    """
    rows = np.array([[0], [0], [0], [1]])
    inner = np.array([0, 1, 2, 0])
    assert transfer._runs(rows, inner) == [3, 1]
    # input order must not matter
    perm = np.array([2, 0, 3, 1])
    assert transfer._runs(rows[perm], inner[perm]) == [3, 1]
    # multi-column row keys: same inner range, different rows -> no coalesce
    rows2 = np.array([[0, 0], [0, 1], [0, 1], [0, 0]])
    inner2 = np.array([0, 1, 2, 1])
    assert sorted(transfer._runs(rows2, inner2)) == [2, 2]
    assert transfer._runs(np.empty((0, 1), np.int64),
                          np.empty(0, np.int64)) == []


def test_burst_init_cost_dominates_minimal():
    model = transfer.TransferModel(bus_bits=64, burst_init=8)
    assert model.transaction_cycles(64) == 9
    assert model.transaction_cycles(64 * 10) == 18
    # max beats splitting
    big = model.transaction_cycles(64 * 1000)
    assert big == 8 * 4 + 1000


def test_2d_contiguity_gains():
    """jacobi-2d: MARS layout beats bbox/minimal on transactions (paper §5.2.3:
    gains are due to contiguity in higher dims)."""
    spec = stencil.SPECS["jacobi-2d"]((4, 5, 7))
    a = mars.analyze(spec)
    lr = layout.layout_for_analysis(a)
    m = transfer.TileIOModel(spec, a, lr)
    io_mars = m.tile_io("float", "mars")
    io_min = m.tile_io("float", "minimal")
    assert io_mars.read_transactions == 10
    # with the corrected _runs coalescing (row key primary), the minimal
    # footprint of this tile coalesces to exactly 20 read bursts — still
    # twice the MARS layout's, at nearly double the cycles
    assert io_min.read_transactions == 20
    assert io_min.read_transactions >= 2 * io_mars.read_transactions
    assert io_mars.total_cycles < io_min.total_cycles
