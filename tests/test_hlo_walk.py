"""HLO walker: trip-count-aware FLOPs/collective accounting."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.launch import hlo_walk


def test_scan_matmul_flops_counted_with_trip_count():
    """scan of k matmuls must count k * 2n^3 flops, not 1 * 2n^3."""
    n, k = 128, 10

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=k)
        return out

    lowered = jax.jit(f).lower(jnp.ones((n, n)), jnp.ones((n, n)))
    compiled = lowered.compile()
    res = hlo_walk.analyze_hlo(compiled.as_text())
    want = k * 2 * n ** 3
    assert 0.9 * want <= res["flops"] <= 1.2 * want, (res["flops"], want)
    # XLA's own analysis undercounts the loop body (the reason this walker
    # exists) — verify we did better whenever XLA undercounts
    xla = float(hlo_walk.cost_analysis_dict(compiled).get("flops", 0.0))
    assert res["flops"] >= xla * 0.9


def test_nested_scan_flops_multiply_trip_counts():
    """scan-of-scans: body FLOPs must scale by the product of trip counts."""
    n, k_outer, k_inner = 64, 3, 4

    def f(x, w):
        def inner(ci, _):
            return ci @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=k_inner)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=k_outer)
        return out

    compiled = jax.jit(f).lower(jnp.ones((n, n)), jnp.ones((n, n))).compile()
    res = hlo_walk.analyze_hlo(compiled.as_text())
    want = k_outer * k_inner * 2 * n ** 3
    assert 0.9 * want <= res["flops"] <= 1.2 * want, (res["flops"], want)


def test_unrolled_matches_scan_counts():
    n, k = 64, 6

    def f_scan(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=k)[0]

    def f_unrolled(x, w):
        for _ in range(k):
            x = x @ w
        return x

    args = (jnp.ones((n, n)), jnp.ones((n, n)))
    r1 = hlo_walk.analyze_hlo(jax.jit(f_scan).lower(*args).compile().as_text())
    r2 = hlo_walk.analyze_hlo(jax.jit(f_unrolled).lower(*args).compile().as_text())
    assert abs(r1["flops"] - r2["flops"]) / r2["flops"] < 0.1


def test_traffic_nonzero_and_scoped_tagging():
    def f(x):
        with jax.named_scope("flash_attn_interior"):
            def body(c, _):
                return c * 2.0 + 1.0, None
            y, _ = jax.lax.scan(body, x, None, length=5)
        return y + x

    compiled = jax.jit(f).lower(jnp.ones((256, 256))).compile()
    res = hlo_walk.analyze_hlo(compiled.as_text())
    assert res["traffic_bytes"] > 0
    assert res["scoped_traffic"].get("flash_attn_interior", 0) > 0
    assert res["scoped_traffic"]["flash_attn_interior"] <= res["traffic_bytes"]


def test_collective_parse_from_text():
    txt = '''
HloModule test

ENTRY %main.1 (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%p), to_apply=%add.1
  ROOT %out = f32[16,128]{1,0} add(%p, %ar)
}
'''
    res = hlo_walk.analyze_hlo(txt)
    assert res["collectives"]["all-gather"] == 64 * 128 * 4
    assert res["collectives"]["all-reduce"] == 16 * 128 * 4
