"""Multi-device semantics (8 host devices, isolated subprocesses):

* sharded-mesh train step equals the single-device step;
* compressed cross-pod gradient exchange (the paper technique) learns and
  tracks the uncompressed baseline (error feedback);
* elastic re-mesh: checkpoint on mesh (4,2) restores and continues on (2,4)
  bit-compatibly with an uninterrupted run.
"""
import os
import subprocess
import sys

import pytest

_MAIN = os.path.join(os.path.dirname(__file__), "_distributed_main.py")


def _run(scenario, timeout=560):
    r = subprocess.run([sys.executable, _MAIN, scenario],
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"{scenario}:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    assert f"OK {scenario}" in r.stdout


@pytest.mark.slow
def test_dist_equivalence():
    _run("dist_equivalence")


@pytest.mark.slow
def test_compressed_grads():
    _run("compressed_grads")


@pytest.mark.slow
def test_remesh():
    _run("remesh")
