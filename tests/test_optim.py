"""AdamW: convergence, clipping, low-precision state."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import adamw


def test_converges_on_quadratic():
    cfg = adamw.AdamConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                           total_steps=200)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw.init(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        return adamw.update(g, state, params, cfg)

    for _ in range(150):
        params, state = step(params, state)
    assert np.abs(np.asarray(params["x"])).max() < 1e-2


def test_grad_clip_limits_update():
    cfg = adamw.AdamConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                           warmup_steps=0)
    params = {"x": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    g = {"x": jnp.array([1e6, -1e6, 1e6])}
    p2, _ = adamw.update(g, state, params, cfg)
    # step magnitude bounded by lr regardless of the huge gradient
    assert np.abs(np.asarray(p2["x"])).max() <= 1.0 + 1e-6


def test_bf16_state_dtype():
    cfg = adamw.AdamConfig(dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = adamw.init(params, cfg)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
    p2, st2 = adamw.update(g, st, params, cfg)
    assert st2.mu["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_weight_decay_skips_vectors():
    cfg = adamw.AdamConfig(lr=1e-2, weight_decay=0.5, warmup_steps=0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = adamw.init(params, cfg)
    g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    p2, _ = adamw.update(g, st, params, cfg)
    assert np.all(np.asarray(p2["w"]) < 1.0)   # decayed
    assert np.allclose(np.asarray(p2["b"]), 1.0)  # not decayed
