"""Checkpoint manager: atomicity, keep-k, dtype fidelity, restore."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (16, 8), jnp.bfloat16),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    mgr.save(5, t, extra={"data_step": 5})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, extra = mgr.restore(5, like)
    assert extra == {"data_step": 5}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_tmp_dirs_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.all_steps() == [1]  # interrupted write is invisible


def test_idempotent_publish(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, _tree())
    mgr.save(7, _tree(1))  # same step again: first publish wins, no crash
    assert mgr.all_steps() == [7]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    mgr.save(3, t)
    mgr.wait()
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, _ = mgr.restore(3, like)
    assert np.array_equal(np.asarray(out["nested"]["b"]), np.arange(7))
