"""End-to-end behaviour of the paper's system (reproduction + framework)."""
import numpy as np

from repro.core import layout, mars, stencil, transfer
from repro.core.executor import Jacobi1dMarsExecutor


def test_full_paper_pipeline_jacobi1d():
    """Analysis -> ILP layout -> codec -> tiled execution -> cycle model."""
    spec = stencil.jacobi1d_spec((6, 6))
    analysis = mars.analyze(spec)
    assert (analysis.n_in, analysis.n_out) == (7, 4)        # Table 1
    lay = layout.layout_for_analysis(analysis)
    assert (lay.read_bursts, lay.write_bursts) == (3, 1)    # Table 1

    n, tsteps = 120, 48
    init = np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, n)) + 1.0
    ex = Jacobi1dMarsExecutor(spec, n, tsteps, dtype="fixed18")
    out = ex.run(init)
    ref = stencil.jacobi1d_reference(init, tsteps)[tsteps]
    assert np.abs(out - ref).max() < 1e-2
    assert ex.stats.compressed_bits < ex.stats.uncompressed_bits

    # the compressed-MARS pattern must beat every non-MARS pattern
    spec64 = stencil.jacobi1d_spec((64, 64))
    a64 = mars.analyze(spec64)
    l64 = layout.layout_for_analysis(a64)
    init2 = np.cumsum(np.random.default_rng(1).uniform(-0.01, 0.01, 250)) + 1.0
    hist = stencil.jacobi1d_reference(init2, 160)
    # interior tile around (t, i) = (100, 100): it and its producers stay
    # inside the computed domain
    rep = tuple(int(x) for x in spec64.tile_of(np.array([[100, 100]]))[0])
    m = transfer.TileIOModel(spec64, a64, l64, rep_tile=rep)
    cyc = {mode: m.tile_io("fixed18", mode, hist=hist).total_cycles
           for mode in transfer.MODES}
    assert cyc["mars_comp"] == min(cyc.values())


def test_serving_system_roundtrip():
    """Config -> smoke model -> serve with packed int8 cache."""
    from repro.configs import base
    from repro.serve.engine import ServeEngine

    cfg = base.load_smoke("granite-8b")
    rc = base.RunConfig(seq_len=64, global_batch=4, kind="decode",
                        remat=False, kv_cache_bits=8)
    eng = ServeEngine(cfg, rc)
    outs = eng.generate([[1, 2, 3], [7], [5, 6], [9, 9, 9]], max_new=6)
    assert all(len(o) == 6 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)
