"""Flash-attention Pallas kernel vs blockwise reference (interpret mode)."""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import blockwise_attention


def _inputs(B, S, KV, G, D, seed=0, dtype=jnp.float32, Sk=None):
    rng = np.random.default_rng(seed)
    Sk = Sk or S
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, D)), dtype)
    return q, k, v


def _ref(q, k, v, causal=True, window=0, bq=64, bk=64):
    B, S, KV, G, D = q.shape
    o = blockwise_attention(q.reshape(B, S, KV * G, D), k, v, causal=causal,
                            window=window, q_block=bq, kv_block=bk)
    return o.reshape(B, S, KV, G, D)


@pytest.mark.parametrize("B,S,KV,G,D", [
    (1, 128, 1, 1, 64), (2, 256, 2, 2, 64), (1, 256, 4, 1, 128),
    (1, 512, 2, 4, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_forward_matches_reference(B, S, KV, G, D, causal):
    q, k, v = _inputs(B, S, KV, G, D)
    o_ref = _ref(q, k, v, causal=causal)
    o = flash_attention(q, k, v, causal, 0, 64, 64, True)
    err = float(jnp.abs(o - o_ref).max())
    assert err < 2e-5, err


@pytest.mark.parametrize("window", [32, 64])
def test_sliding_window(window):
    q, k, v = _inputs(1, 256, 2, 2, 64, seed=1)
    o_ref = _ref(q, k, v, causal=True, window=window)
    o = flash_attention(q, k, v, True, window, 64, 64, True)
    assert float(jnp.abs(o - o_ref).max()) < 2e-5


def test_bf16_forward():
    q, k, v = _inputs(1, 128, 2, 2, 64, dtype=jnp.bfloat16)
    o_ref = _ref(q, k, v)
    o = flash_attention(q, k, v, True, 0, 64, 64, True)
    assert float(jnp.abs(o.astype(jnp.float32)
                         - o_ref.astype(jnp.float32)).max()) < 3e-2


@pytest.mark.parametrize("B,S,KV,G,D", [(1, 128, 1, 1, 64), (1, 128, 2, 2, 64)])
def test_gradients_match_reference(B, S, KV, G, D):
    q, k, v = _inputs(B, S, KV, G, D, seed=2)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, True, 0, 64, 64, True)
        return jnp.sum(o * jnp.cos(o))

    def loss_ref(q, k, v):
        o = _ref(q, k, v)
        return jnp.sum(o * jnp.cos(o))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "q k v".split()):
        err = float(jnp.abs(a - b).max())
        rel = err / (float(jnp.abs(b).max()) + 1e-9)
        assert rel < 2e-4, (name, err, rel)
