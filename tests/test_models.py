"""Per-architecture smoke tests + decode-path consistency."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import model_zoo, transformer


def _batch_for(cfg, rc, seed=0):
    specs = model_zoo.input_specs(cfg, rc)
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), dtype=s.dtype)
    return out


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one full train step, finite results."""
    cfg = base.load_smoke(arch)
    rc = base.RunConfig(seq_len=64, global_batch=2, kind="train", remat=False,
                        q_block=32, kv_block=32)
    api = model_zoo.get_api(cfg, rc)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rc)
    loss = jax.jit(api.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    from repro.train import step as ts
    step = ts.make_train_step(api, cfg, rc, None)
    state = ts.init_state(api, rc, jax.random.PRNGKey(0))
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)))
    assert moved


@pytest.mark.parametrize("arch", base.ARCH_IDS)
@pytest.mark.parametrize("bits", [16, 8])
def test_smoke_decode(arch, bits):
    cfg = base.load_smoke(arch)
    rc = base.RunConfig(seq_len=96, global_batch=2, kind="decode", remat=False,
                        q_block=32, kv_block=32, kv_cache_bits=bits)
    api = model_zoo.get_api(cfg, rc)
    params = api.init(jax.random.PRNGKey(0))
    state = api.init_decode_state(2)
    step = jax.jit(api.decode_step)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(4):
        lg, state = step(params, state, tok)
    assert lg.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = base.load_smoke(arch)
    rc = base.RunConfig(seq_len=32, global_batch=2, kind="decode", remat=False,
                        q_block=16, kv_block=16, param_dtype="float32")
    api = model_zoo.get_api(cfg, rc)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(2, 16), dtype=np.int32))
    lg_full, _ = transformer.forward(params, toks, cfg, rc)
    state = api.init_decode_state(2)
    step = jax.jit(api.decode_step)
    errs = []
    for i in range(16):
        lg, state = step(params, state, toks[:, i])
        errs.append(float(np.abs(np.asarray(lg) - np.asarray(lg_full[:, i])).max()))
    assert max(errs) < 2e-2, errs


def test_moe_decode_matches_with_no_drop_capacity():
    cfg = dataclasses.replace(base.load_smoke("mixtral-8x7b"),
                              capacity_factor=8.0)
    rc = base.RunConfig(seq_len=32, global_batch=2, kind="decode", remat=False,
                        q_block=16, kv_block=16, param_dtype="float32")
    api = model_zoo.get_api(cfg, rc)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(2, 12), dtype=np.int32))
    lg_full, _ = transformer.forward(params, toks, cfg, rc)
    state = api.init_decode_state(2)
    step = jax.jit(api.decode_step)
    for i in range(12):
        lg, state = step(params, state, toks[:, i])
        err = float(np.abs(np.asarray(lg) - np.asarray(lg_full[:, i])).max())
        assert err < 2e-4, (i, err)


def test_sliding_window_ring_cache_equals_full_cache():
    """SWA ring buffer (long_500k mechanism) == full cache with window mask."""
    cfg = base.load_smoke("mixtral-8x7b")          # window 64
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, sliding_window=8)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab, size=(1, 24), dtype=np.int32))
    outs = {}
    for cache_len in (8, 32):   # ring (== window) vs oversized cache
        rc = base.RunConfig(seq_len=cache_len, global_batch=1, kind="decode",
                            remat=False, q_block=16, kv_block=16,
                            param_dtype="float32")
        api = model_zoo.get_api(cfg, rc)
        params = api.init(jax.random.PRNGKey(0))
        state = api.init_decode_state(1)
        step = jax.jit(api.decode_step)
        lgs = []
        for i in range(24):
            lg, state = step(params, state, toks[:, i])
            lgs.append(np.asarray(lg))
        outs[cache_len] = np.stack(lgs)
    assert np.allclose(outs[8], outs[32], atol=2e-4), \
        np.abs(outs[8] - outs[32]).max()


def test_vlm_prefix_changes_text_logits():
    cfg = base.load_smoke("internvl2-76b")
    rc = base.RunConfig(seq_len=24, global_batch=2, kind="train", remat=False,
                        q_block=16, kv_block=16)
    api = model_zoo.get_api(cfg, rc)
    params = api.init(jax.random.PRNGKey(0))
    b = _batch_for(cfg, rc)
    l1 = float(jax.jit(api.loss_fn)(params, b))
    b2 = dict(b, vis_embeds=b["vis_embeds"] + 1.0)
    l2 = float(jax.jit(api.loss_fn)(params, b2))
    assert l1 != l2


def test_param_counts_match_published_order():
    """Full configs: param_count within 15% of the published size."""
    expect = {
        "tinyllama-1.1b": 1.1e9, "yi-9b": 8.8e9, "granite-8b": 8.1e9,
        "mixtral-8x7b": 46.7e9, "mamba2-130m": 130e6,
        "qwen1.5-110b": 111e9, "grok-1-314b": 314e9,
        "internvl2-76b": 70e9,   # LLM backbone of the 76B (vision tower excl.)
        "whisper-tiny": 39e6, "hymba-1.5b": 1.52e9,
    }
    for arch, n in expect.items():
        got = base.load_arch(arch).param_count()
        assert abs(got - n) / n < 0.12, (arch, got, n)
    # MoE active counts (top-2 of 8)
    assert abs(base.load_arch("mixtral-8x7b").active_param_count() - 12.9e9) \
        / 12.9e9 < 0.05
