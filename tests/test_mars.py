"""MARS analysis: paper Table-1 validation + structural invariants."""
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import layout, mars, stencil


TABLE1 = [
    # (name, tile_sizes, n_in, n_out, read_bursts, write_bursts)
    ("jacobi-1d", (6, 6), 7, 4, 3, 1),
    ("jacobi-1d", (64, 64), 7, 4, 3, 1),
    ("jacobi-1d", (200, 200), 7, 4, 3, 1),
    ("jacobi-2d", (4, 5, 7), 28, 13, 10, 1),
    ("jacobi-2d", (10, 10, 10), 28, 13, 10, 1),
    ("seidel-2d", (4, 10, 10), 33, 13, 10, 1),
]


@pytest.mark.parametrize("name,ts,n_in,n_out,rb,wb", TABLE1)
def test_table1(name, ts, n_in, n_out, rb, wb):
    spec = stencil.SPECS[name](ts)
    a = mars.analyze(spec)
    assert a.n_in == n_in, (a.n_in, n_in)
    assert a.n_out == n_out
    lr = layout.layout_for_analysis(a)
    assert lr.read_bursts == rb
    assert lr.write_bursts == wb
    assert lr.exact


def test_jacobi1d_diamond_holds_18_points():
    a = mars.analyze(stencil.jacobi1d_spec((6, 6)))
    assert a.tile_points == 18  # paper Fig. 1


@pytest.mark.parametrize("name,ts", [(n, t) for n, t, *_ in TABLE1])
def test_partition_invariants(name, ts):
    """Irredundancy: out-MARS are disjoint and cover the flow-out set."""
    spec = stencil.SPECS[name](ts)
    a = mars.analyze(spec)
    mars.check_partition(a)
    # every consumed input MARS id references an existing out MARS
    for producer, ids in a.consumed.items():
        assert all(0 <= i < a.n_out for i in ids)
        assert producer != tuple([0] * spec.ndim)


def test_translation_invariance():
    """MARS structure identical for different representative tiles."""
    spec = stencil.jacobi1d_spec((6, 6))
    a1 = mars.analyze(spec, rep_tile=(64, 64))
    a2 = mars.analyze(spec, rep_tile=(11, 29))
    assert [m.consumers for m in a1.out_mars] == [m.consumers for m in a2.out_mars]
    assert [m.size for m in a1.out_mars] == [m.size for m in a2.out_mars]
    assert a1.consumed == a2.consumed


def test_atomicity():
    """Every point of a consumed MARS is read by the consuming tile."""
    spec = stencil.jacobi1d_spec((6, 6))
    a = mars.analyze(spec, rep_tile=(40, 40))
    reads = np.asarray(spec.reads)
    c0 = np.array([40, 40])
    # gather all points the tile actually reads from outside
    pts = mars._enumerate_tile_points(spec, c0)
    read_pts = (pts[:, None, :] + reads[None, :, :]).reshape(-1, 2)
    ext = {tuple(p) for p in read_pts
           if tuple(spec.tile_of(p[None])[0]) != (40, 40)}
    for producer_off, ids in a.consumed.items():
        pa = mars.analyze(spec, tuple(c0 + np.array(producer_off)))
        for mid in ids:
            for p in pa.out_mars[mid].points:
                assert tuple(p) in ext, (producer_off, mid, p)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 12), st.integers(4, 12))
def test_mars_partition_property_random_tiles(t0, t1):
    """Partition invariants hold across random diamond tile sizes."""
    spec = stencil.jacobi1d_spec((t0 * 2, t1 * 2))  # even => diamonds nonempty
    a = mars.analyze(spec)
    mars.check_partition(a)
    assert a.n_out >= 1 and a.n_in >= 1
    sizes = sum(m.size for m in a.out_mars)
    assert sizes < a.tile_points * len(spec.reads)
