"""Observability layer: registry semantics, spans, sinks, zero-cost path,
and the end-to-end guarantee that published transfer counters equal the
values `repro.core.transfer` returns directly (ISSUE 6 acceptance)."""
import json
import os

import numpy as np
import pytest

from repro import obs
from repro.core import layout, mars, stencil, transfer
from repro.core.executor import ExecStats, Jacobi1dMarsExecutor
from repro.core.stencil import jacobi1d_spec


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_label_semantics():
    reg = obs.Registry()
    reg.counter("transfer/cycles", pattern="mars", dtype="fixed18").inc(10)
    reg.counter("transfer/cycles", pattern="mars", dtype="fixed18").inc(5)
    reg.counter("transfer/cycles", pattern="bbox", dtype="fixed18").inc(7)
    # same name+labels accumulates into one series; different labels split
    assert reg.counter_value("transfer/cycles", pattern="mars",
                             dtype="fixed18") == 15
    assert reg.counter_value("transfer/cycles", pattern="bbox",
                             dtype="fixed18") == 7
    assert reg.counter_value("transfer/cycles", pattern="minimal",
                             dtype="fixed18") == 0
    # label order does not matter for series identity
    key1 = obs.series_key("m", {"b": 1, "a": 2})
    key2 = obs.series_key("m", {"a": 2, "b": 1})
    assert key1 == key2 == "m{a=2,b=1}"
    assert len(reg.series("transfer/cycles")) == 2


def test_counter_rejects_negative():
    reg = obs.Registry()
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)


def test_gauge_and_histogram():
    reg = obs.Registry()
    reg.gauge("serve/kv_bytes", arch="tiny").set(123)
    reg.gauge("serve/kv_bytes", arch="tiny").set(456)
    h = reg.histogram("train/step_ms")
    for v in (1.0, 2.0, 4.0, 1000.0):
        h.observe(v)
    snap = reg.snapshot().to_dict()
    assert snap["gauges"]["serve/kv_bytes{arch=tiny}"] == 456
    hs = snap["histograms"]["train/step_ms"]
    assert hs["count"] == 4 and hs["min"] == 1.0 and hs["max"] == 1000.0
    assert hs["mean"] == pytest.approx(1007.0 / 4)
    # power-of-two buckets: 1.0 -> b0, 2.0 -> b1, 4.0 -> b2, 1000 -> b10
    assert hs["buckets"] == {"0": 1, "1": 1, "2": 1, "10": 1}


def test_snapshot_reset():
    reg = obs.Registry()
    reg.counter("a").inc()
    assert len(reg) == 1
    reg.reset()
    assert len(reg) == 0
    assert reg.snapshot().to_dict() == {
        "counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_export():
    tr = obs.Tracer()
    with tr.span("outer", tile=(1, 2)) as sp_out:
        with tr.span("inner") as sp_in:
            sp_in.add_cycles(100)
        with tr.span("inner") as sp_in2:
            sp_in2.add_cycles(50)
    assert [r.name for r in tr.records] == ["inner", "inner", "outer"]
    assert [r.depth for r in tr.records] == [1, 1, 0]
    # logical cycles roll up into the enclosing span
    outer = tr.records[-1]
    assert outer.cycles == 150
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    assert len(evs) == 3 and all(e["ph"] == "X" for e in evs)
    # sorted by start time: outer first, then the two inners
    assert [e["name"] for e in evs] == ["outer", "inner", "inner"]
    assert evs[0]["args"] == {"tile": "(1, 2)"} or \
        evs[0]["args"]["tile"] == (1, 2)
    assert evs[0]["args"]["cycles"] == 150
    for e in evs:
        assert e["dur"] >= 0 and "ts" in e and "pid" in e and "tid" in e
    json.dumps(doc)  # must be serializable


def test_span_exception_still_closes():
    tr = obs.Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert len(tr.records) == 1 and tr.depth == 0


# ---------------------------------------------------------------------------
# instrument: enable/disable gating
# ---------------------------------------------------------------------------

def test_disabled_records_nothing():
    obs.disable()
    before = len(obs.instrument.registry())
    obs.counter_inc("never", 1)
    obs.gauge_set("never", 1)
    obs.hist_observe("never", 1)
    with obs.span("never") as sp:
        sp.add_cycles(10)
        sp.set(a=1)
    assert len(obs.instrument.registry()) == before
    assert obs.instrument.registry().counter_value("never") == 0
    # disabled span path allocates nothing: same shared null context
    assert obs.span("a") is obs.span("b")


def test_enabled_scope_restores_state():
    obs.disable()
    with obs.enabled_scope() as (reg, tr):
        assert obs.enabled()
        obs.counter_inc("x", 2)
        with obs.span("s"):
            pass
        assert reg.counter_value("x") == 2
        assert len(tr.records) == 1
    assert not obs.enabled()
    # scope sinks were private: global registry untouched
    assert obs.instrument.registry().counter_value("x") == 0


def test_instrumented_decorator():
    calls = []

    @obs.instrumented("myfn", tag="t")
    def fn(a):
        calls.append(a)
        return a + 1

    obs.disable()
    assert fn(1) == 2  # plain passthrough when disabled
    with obs.enabled_scope() as (reg, tr):
        assert fn(2) == 3
        snap = reg.snapshot().to_dict()
        assert snap["histograms"]["myfn_ms{tag=t}"]["count"] == 1
        assert [r.name for r in tr.records] == ["myfn"]
    assert calls == [1, 2]


# ---------------------------------------------------------------------------
# end-to-end: core/transfer publishes exactly what it returns
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jacobi_model():
    spec = stencil.SPECS["jacobi-1d"]((64, 64))
    a = mars.analyze(spec)
    lr = layout.layout_for_analysis(a)
    rep = tuple(int(x) for x in spec.tile_of(np.array([[150, 2000]]))[0])
    m = transfer.TileIOModel(spec, a, lr, rep_tile=rep)
    init = np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, 4000)) + 1.0
    hist = stencil.jacobi1d_reference(init, 300)
    return m, hist


def test_transfer_counters_match_direct_values(jacobi_model):
    """ISSUE 6 acceptance: reported mars_comp cycles == transfer.py values."""
    m, hist = jacobi_model
    with obs.enabled_scope() as (reg, _):
        ios = {mode: m.tile_io("fixed18", mode, hist=hist)
               for mode in transfer.MODES}
    labels = dict(bench="jacobi-1d", tile="64x64", dtype="fixed18")
    for mode, io in ios.items():
        assert reg.counter_value("transfer/cycles", pattern=mode,
                                 **labels) == io.total_cycles
        assert reg.counter_value("transfer/bits", pattern=mode, dir="read",
                                 **labels) == io.read_bits
        assert reg.counter_value("transfer/transactions", pattern=mode,
                                 dir="write", **labels) \
            == io.write_transactions
    assert reg.counter_value("transfer/cycles", pattern="mars_comp",
                             **labels) == ios["mars_comp"].total_cycles


def test_transfer_span_charged_cycles(jacobi_model):
    m, hist = jacobi_model
    with obs.enabled_scope() as (_, tr):
        with tr.span("tile_io"):
            io = m.tile_io("fixed18", "mars_comp", hist=hist)
    assert tr.records[-1].cycles == io.total_cycles


def test_disabling_obs_changes_no_result(jacobi_model):
    """The TileIO numbers are identical with obs on and off."""
    m, hist = jacobi_model
    obs.disable()
    off = m.tile_io("fixed18", "mars_comp", hist=hist)
    with obs.enabled_scope():
        on = m.tile_io("fixed18", "mars_comp", hist=hist)
    assert on == off


def test_executor_publishes_stats():
    rng = np.random.default_rng(2)
    init = np.cumsum(rng.uniform(-0.005, 0.005, 80)) + 0.5
    with obs.enabled_scope() as (reg, tr):
        ex = Jacobi1dMarsExecutor(jacobi1d_spec((6, 6)), 80, 30,
                                  dtype="fixed18")
        ex.run(init)
    labels = dict(bench="jacobi-1d", dtype="fixed18")
    assert reg.counter_value("exec/full_tiles", **labels) \
        == ex.stats.full_tiles
    assert reg.counter_value("exec/compressed_bits", **labels) \
        == ex.stats.compressed_bits
    assert reg.counter_value("exec/mars_written", **labels) \
        == ex.stats.mars_written
    # compress_mars_stream emitted per-MARS histograms + the run root span
    snap = reg.snapshot().to_dict()
    comp_series = [k for k in snap["histograms"]
                   if k.startswith("compression/mars_bits")]
    assert comp_series
    assert any(r.name == "executor/run" for r in tr.records)


def test_execstats_publish_is_noop_when_disabled():
    obs.disable()
    ExecStats(full_tiles=3).publish(bench="x")
    assert obs.instrument.registry().counter_value(
        "exec/full_tiles", bench="x") == 0


# ---------------------------------------------------------------------------
# sinks + report
# ---------------------------------------------------------------------------

def test_sink_summary_jsonl_sidecar_roundtrip(tmp_path):
    with obs.enabled_scope() as (reg, tr):
        obs.counter_inc("transfer/cycles", 42, pattern="mars_comp",
                        bench="jacobi-1d", tile="6x6", dtype="fixed18")
        obs.hist_observe("compression/ratio", 5.0, dtype="fixed18")
        with obs.span("bench/fig10"):
            pass
        doc = obs.summary(reg, tr, meta={"config": "test"})
        jl = obs.write_jsonl(str(tmp_path / "obs.jsonl"), reg, tr,
                             meta={"config": "test"})
        sc = obs.write_sidecar(str(tmp_path), reg, tr,
                               meta={"config": "test"})
    assert doc["meta"]["config"] == "test"
    key = ("transfer/cycles{bench=jacobi-1d,dtype=fixed18,"
           "pattern=mars_comp,tile=6x6}")
    assert doc["metrics"]["counters"][key] == 42
    assert doc["spans"][0]["name"] == "bench/fig10"

    lines = [json.loads(l) for l in open(jl)]
    kinds = {l["kind"] for l in lines}
    assert {"meta", "counter", "histogram", "span"} <= kinds
    ctr = next(l for l in lines if l["kind"] == "counter")
    assert ctr["name"] == "transfer/cycles"
    assert ctr["labels"]["pattern"] == "mars_comp" and ctr["value"] == 42

    loaded = obs.read_summary(str(tmp_path))  # resolves the sidecar name
    assert loaded == json.load(open(sc))
    assert os.path.exists(tmp_path / "trace.json")
    chrome = json.load(open(tmp_path / "trace.json"))
    assert chrome["traceEvents"][0]["name"] == "bench/fig10"


def test_report_renders_patterns(tmp_path, capsys):
    from repro.obs import report
    with obs.enabled_scope() as (reg, tr):
        for pat, cyc in [("minimal", 700), ("bbox", 300), ("mars", 200),
                         ("mars_pack", 150), ("mars_comp", 100)]:
            obs.counter_inc("transfer/cycles", cyc, pattern=pat,
                            bench="jacobi-1d", tile="6x6", dtype="fixed18")
        obs.hist_observe("compression/ratio", 5.0, dtype="fixed18")
        obs.write_sidecar(str(tmp_path), reg, tr, meta={"config": "t"})
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    for pat in transfer.MODES:
        assert pat in out
    assert "compression/ratio" in out
    # the pivoted row holds the per-pattern values in MODES order
    row = next(l for l in out.splitlines() if "jacobi-1d" in l)
    assert [c.strip() for c in row.split("|")[4:9]] \
        == ["700", "300", "200", "150", "100"]


def test_run_metadata_stamps_git():
    meta = obs.run_metadata(config="x", seed=7)
    assert meta["config"] == "x" and meta["seed"] == 7
    # inside this repo the SHA resolves to a 40-hex string
    assert meta["git_sha"] is None or len(meta["git_sha"]) == 40
