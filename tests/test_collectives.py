"""Compressed-exchange codec pieces + cross-pod HLO attribution."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed import collectives as C
from repro.launch import hlo_walk


def test_quant_lastdim_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 8, 128)), jnp.float32)
    for bits in (4, 6, 8):
        planes, scale = C._quant_lastdim(x, bits)
        y = C._dequant_lastdim(planes, scale, bits, x.shape)
        step = np.asarray(jnp.max(jnp.abs(x.reshape(6, 8, 4, 32)), -1)
                          / (2 ** (bits - 1) - 1))
        err = np.abs(np.asarray(x - y)).reshape(6, 8, 4, 32).max(-1)
        assert (err <= step + 1e-6).all(), bits


def test_quant_preserves_shape_and_wire_size():
    x = jnp.ones((4, 64), jnp.float32)
    planes, scale = C._quant_lastdim(x, 8)
    assert planes.shape == (4, 2, 8)      # 64 -> 2 groups x 8 planes
    assert scale.shape == (4, 2)
    # wire bytes per param: 8 bits + one f32 scale per 32 values
    assert abs(C.compressed_bytes_per_param(8) - (1.0 + 4 / 32)) < 1e-9


def test_compressible_criteria():
    assert C.compressible(jnp.zeros((128, 128)))
    assert not C.compressible(jnp.zeros((10,)))          # tiny
    assert not C.compressible(jnp.zeros((4096, 31)))     # last dim not /32


def test_error_feedback_converges_unbiased():
    """Repeated compress of a constant with error feedback: mean of the
    decompressed stream -> the true value (the paper-codec lossy analogue)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    resid = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 24
    for _ in range(n):
        x = g + resid
        planes, scale = C._quant_lastdim(x, 4)
        approx = C._dequant_lastdim(planes, scale, 4, x.shape)
        resid = x - approx
        acc = acc + approx
    err = float(jnp.abs(acc / n - g).max())
    one_shot = float(jnp.abs(
        C._dequant_lastdim(*C._quant_lastdim(g, 4), 4, g.shape) - g).max())
    assert err < one_shot / 3, (err, one_shot)


def test_xpod_attribution_parsing():
    assert hlo_walk._crosses_pod(
        "x, replica_groups=[256,2]<=[2,256]T(1,0), etc") is True
    assert hlo_walk._crosses_pod(
        "x, replica_groups=[32,16]<=[512], etc") is False
    assert hlo_walk._crosses_pod("x, replica_groups={{0,256},{1,257}}") is True
    assert hlo_walk._crosses_pod("x, replica_groups={{0,16},{1,17}}") is False
    assert hlo_walk._crosses_pod("x, source_target_pairs={{0,256},{1,257}}") \
        is True
    assert hlo_walk._crosses_pod("no groups here") is None
