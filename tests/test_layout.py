"""Algorithm 1 (layout ILP): optimality and burst accounting."""
import itertools

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import layout


def _random_instance(draw):
    n = draw(st.integers(2, 7))
    n_consumers = draw(st.integers(1, 5))
    sets = []
    for _ in range(n_consumers):
        members = draw(st.lists(st.integers(0, n - 1), min_size=1,
                                max_size=n, unique=True))
        sets.append(members)
    return n, sets


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_exact_matches_brute_force(data):
    n, sets = _random_instance(data.draw)
    got = layout.solve_layout(n, sets)
    ref = layout.brute_force_layout(n, sets)
    assert got.contiguities == ref.contiguities
    assert got.read_bursts == ref.read_bursts
    assert sorted(got.order) == list(range(n))  # valid permutation


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_bursts_equal_sets_minus_contiguities(data):
    n, sets = _random_instance(data.draw)
    r = layout.solve_layout(n, sets)
    # each adjacency shared by a consumer saves exactly one burst
    total = sum(len(set(s)) for s in sets)
    assert r.read_bursts == total - r.contiguities


def test_greedy_fallback_is_permutation():
    n = layout.EXACT_LIMIT + 4
    sets = [list(range(0, n, 2)), list(range(1, n, 2)), list(range(n))]
    r = layout.solve_layout(n, sets)
    assert sorted(r.order) == list(range(n))
    assert not r.exact
    assert r.read_bursts >= 3 - 2  # sanity lower bound


def test_paper_example_layout():
    """§3.2.2: consumers {O2,O3,O4}, {O2}, {O1,O2,O3} -> 3 read bursts."""
    consumed = [[1, 2, 3], [1], [0, 1, 2]]
    r = layout.solve_layout(4, consumed)
    assert r.read_bursts == 3
    assert r.contiguities == 4
