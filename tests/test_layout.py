"""Algorithm 1 (layout ILP): optimality, burst accounting, edge cases."""
import pytest

from repro.core import layout

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip; the deterministic ones run
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need the hypothesis package")


if HAVE_HYPOTHESIS:
    def _random_instance(draw):
        n = draw(st.integers(2, 7))
        n_consumers = draw(st.integers(1, 5))
        sets = []
        for _ in range(n_consumers):
            members = draw(st.lists(st.integers(0, n - 1), min_size=1,
                                    max_size=n, unique=True))
            sets.append(members)
        return n, sets

    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_exact_matches_brute_force(data):
        n, sets = _random_instance(data.draw)
        got = layout.solve_layout(n, sets)
        ref = layout.brute_force_layout(n, sets)
        assert got.contiguities == ref.contiguities
        assert got.read_bursts == ref.read_bursts
        assert sorted(got.order) == list(range(n))  # valid permutation

    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_bursts_equal_sets_minus_contiguities(data):
        n, sets = _random_instance(data.draw)
        r = layout.solve_layout(n, sets)
        # each adjacency shared by a consumer saves exactly one burst
        total = sum(len(set(s)) for s in sets)
        assert r.read_bursts == total - r.contiguities


def test_greedy_fallback_is_permutation():
    n = layout.EXACT_LIMIT + 4
    sets = [list(range(0, n, 2)), list(range(1, n, 2)), list(range(n))]
    r = layout.solve_layout(n, sets)
    assert sorted(r.order) == list(range(n))
    assert not r.exact
    assert r.read_bursts >= 3 - 2  # sanity lower bound


def test_paper_example_layout():
    """§3.2.2: consumers {O2,O3,O4}, {O2}, {O1,O2,O3} -> 3 read bursts."""
    consumed = [[1, 2, 3], [1], [0, 1, 2]]
    r = layout.solve_layout(4, consumed)
    assert r.read_bursts == 3
    assert r.contiguities == 4


# ---------------------------------------------------------------------------
# Edge cases: degenerate instance sizes and disconnected consumer graphs
# ---------------------------------------------------------------------------

def test_single_mars_instance():
    """n=1: the only order is (0,); one burst per consumer set."""
    r = layout.solve_layout(1, [[0], [0]])
    assert r.order == (0,)
    assert r.read_bursts == 2
    assert r.write_bursts == 1
    bf = layout.brute_force_layout(1, [[0], [0]])
    assert (bf.order, bf.read_bursts) == (r.order, r.read_bursts)
    assert layout.count_bursts(r.order, [[0], [0]]) == 2


def test_two_mars_instance():
    """n=2: pairing the set {0,1} must cost one burst, not two."""
    sets = [[0, 1], [1]]
    r = layout.solve_layout(2, sets)
    assert sorted(r.order) == [0, 1]
    assert r.read_bursts == 2  # {0,1} contiguous (1) + {1} (1)
    bf = layout.brute_force_layout(2, sets)
    assert r.read_bursts == bf.read_bursts == layout.count_bursts(
        r.order, sets)


def test_disconnected_consumer_graph():
    """Two consumer components that share no MARS.

    The adjacency-weight graph is disconnected; the solver must still
    produce one global permutation and charge each component its own
    optimal bursts: {0,1} and {2,3} each collapse to one burst, the
    component boundary saves nothing.
    """
    sets = [[0, 1], [2, 3]]
    r = layout.solve_layout(4, sets)
    assert sorted(r.order) == [0, 1, 2, 3]
    assert r.read_bursts == 2
    bf = layout.brute_force_layout(4, sets)
    assert bf.read_bursts == 2
    assert layout.count_bursts(r.order, sets) == r.read_bursts
    # isolated MARS (never consumed) must not corrupt the accounting
    sets_iso = [[0], [2]]
    r2 = layout.solve_layout(4, sets_iso)
    assert sorted(r2.order) == [0, 1, 2, 3]
    assert r2.read_bursts == 2


def test_held_karp_agreement_small_n():
    """_held_karp is exact at the degenerate sizes n=1 and n=2."""
    import numpy as np

    w1 = np.zeros((1, 1), dtype=np.int64)
    order, score = layout._held_karp(w1)
    assert order == [0] and score == 0

    w2 = np.array([[0, 5], [5, 0]], dtype=np.int64)
    order, score = layout._held_karp(w2)
    assert sorted(order) == [0, 1]
    assert score == 5
