"""End-to-end MARS accelerator simulation vs dense reference (paper §4/§5)."""
import numpy as np
import pytest

from repro.core.executor import Jacobi1dMarsExecutor
from repro.core.stencil import jacobi1d_reference, jacobi1d_spec


@pytest.mark.parametrize("dtype,tol", [
    ("fixed24", 1e-4), ("fixed18", 1e-2), ("float", 1e-5), ("double", 1e-12)])
def test_executor_matches_reference(dtype, tol):
    rng = np.random.default_rng(1)
    n, tsteps = 60, 24
    init = rng.uniform(0.0, 1.0, size=n)
    ex = Jacobi1dMarsExecutor(jacobi1d_spec((6, 6)), n, tsteps, dtype=dtype,
                              record=True)
    out = ex.run(init)
    hist = jacobi1d_reference(init, tsteps)
    assert np.abs(out - hist[tsteps]).max() < tol
    # strict check on every value computed through the MARS+codec path
    assert ex.stats.full_tiles > 20
    devs = [abs(v - hist[t, i]) for (t, i), v in ex.full_tile_values.items()]
    assert max(devs) < tol


def test_executor_compression_stats():
    rng = np.random.default_rng(2)
    init = np.cumsum(rng.uniform(-0.005, 0.005, 80)) + 0.5  # smooth
    ex = Jacobi1dMarsExecutor(jacobi1d_spec((6, 6)), 80, 30, dtype="fixed18")
    ex.run(init)
    assert ex.stats.compressed_bits < ex.stats.uncompressed_bits
    assert ex.stats.mars_read > 0 and ex.stats.mars_written > 0


def test_executor_marker_counts():
    ex = Jacobi1dMarsExecutor(jacobi1d_spec((6, 6)), 60, 12, dtype="fixed24")
    ex.run(np.linspace(0, 1, 60))
    for stream in ex.memory.values():
        assert len(stream.markers) == 4  # one marker per out-MARS (§4.2.2)
