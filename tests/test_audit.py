"""HLO-vs-analytic audit: pure-half unit tests + end-to-end CLI runs.

The CLI tests subprocess ``python -m repro.launch.audit`` (the wire audit
needs the 2-pod host-device mesh the module sets up for itself) and pin
the PR's acceptance criteria: a clean run exits 0 with every wire check
byte-exact, and perturbing the analytic model makes the audit exit
nonzero.
"""
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import audit, hlo_walk  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_cli(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.audit", *extra],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)


# ---------------------------------------------------------------------------
# pure half (no jax compilation)
# ---------------------------------------------------------------------------

def test_check_divergence_flags():
    ok = audit.AuditCheck("a", 100.0, 100.0)
    assert not ok.diverged and ok.rel_error == 0.0
    bad = audit.AuditCheck("b", 100.0, 90.0)
    assert bad.diverged
    loose = audit.AuditCheck("c", 110.0, 100.0, rel_tol=0.25, unit="flops")
    assert not loose.diverged


def test_summarize_and_perturb():
    checks = [audit.AuditCheck("a", 10.0, 10.0),
              audit.AuditCheck("b", 20.0, 20.0)]
    rep = audit.summarize(checks)
    assert rep["ok"] and rep["divergences"] == 0 and rep["n_checks"] == 2
    rep2 = audit.summarize(audit.perturb_analytic(checks, 1.01))
    assert not rep2["ok"] and rep2["divergences"] == 2


def test_ring_wire_bytes_convention():
    # all-gather / reduce-scatter move (g-1)/g of the buffer, all-reduce 2x
    # that, permute the full buffer; g=0 (unknown) uses the asymptotic factor
    assert hlo_walk._ring_wire_bytes("all-gather", 2, 100.0) == 50.0
    assert hlo_walk._ring_wire_bytes("all-reduce", 2, 100.0) == 100.0
    assert hlo_walk._ring_wire_bytes("reduce-scatter", 4, 100.0) == 75.0
    assert hlo_walk._ring_wire_bytes("collective-permute", 2, 100.0) == 100.0
    assert hlo_walk._ring_wire_bytes("all-gather", 0, 100.0) == 100.0


def test_group_info_forms():
    assert hlo_walk._group_info("replica_groups=[4,2]<=[8]") == (2, 4)
    assert hlo_walk._group_info("replica_groups={{0,1},{2,3}}") == (2, 2)
    assert hlo_walk._group_info(
        "source_target_pairs={{0,1},{1,0}}") == (2, 0)
    assert hlo_walk._group_info("no annotation", default_size=8) == (8, 1)


def test_entry_io_bytes_handwritten():
    hlo = """\
HloModule m

%helper (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  ROOT %n = f32[64]{0} negate(f32[64]{0} %a)
}

ENTRY %main (p0: f32[128,4], p1: s32[16]) -> (f32[128,4], s32[16]) {
  %p0 = f32[128,4]{1,0} parameter(0)
  %p1 = s32[16]{0} parameter(1)
  ROOT %t = (f32[128,4]{1,0}, s32[16]{0}) tuple(%p0, %p1)
}
"""
    params, roots = hlo_walk.entry_io_bytes(hlo)
    assert params == 128 * 4 * 4 + 16 * 4
    assert roots == 128 * 4 * 4 + 16 * 4


def test_walker_wire_bytes_handwritten():
    # one all-gather (g=2: wire == operand bytes) + one all-reduce (g=2:
    # wire == buffer bytes), trip-count-free module
    hlo = """\
HloModule m, num_partitions=2

ENTRY %main (p0: u32[8,4], p1: f32[7]) -> (u32[16,4], f32[7]) {
  %p0 = u32[8,4]{1,0} parameter(0)
  %p1 = f32[7]{0} parameter(1)
  %ag = u32[16,4]{1,0} all-gather(u32[8,4]{1,0} %p0), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[7]{0} all-reduce(f32[7]{0} %p1), replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (u32[16,4]{1,0}, f32[7]{0}) tuple(%ag, %ar)
}
"""
    res = hlo_walk.analyze_hlo(hlo)
    details = {d.op: d for d in res["collective_details"]}
    assert details["all-gather"].group_size == 2
    assert details["all-gather"].wire_bytes == 8 * 4 * 4      # (g-1)*operand
    assert details["all-reduce"].wire_bytes == 7 * 4          # 2(g-1)/g*buf
    assert res["collective_wire_bytes"] == {
        "all-gather": 8 * 4 * 4.0, "all-reduce": 7 * 4.0}


# ---------------------------------------------------------------------------
# end to end (subprocess: needs its own multi-device jax runtime)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_clean_run_is_byte_exact(tmp_path):
    out = tmp_path / "report.json"
    r = _run_cli("--json", str(out), "--sizes", "65536", "--bits", "4", "8")
    assert r.returncode == 0, r.stdout + r.stderr
    report = json.loads(out.read_text())
    assert report["ok"] and report["divergences"] == 0
    wire = [c for c in report["checks"] if c["name"].startswith("wire/")]
    assert len(wire) == 2
    for c in wire:
        # the acceptance criterion: HLO-derived collective wire bytes equal
        # the analytic ExchangeStats bytes exactly for every exchanged tree
        assert c["hlo_value"] == c["analytic_value"], c


@pytest.mark.slow
def test_cli_perturbed_analytic_exits_nonzero():
    r = _run_cli("--sizes", "65536", "--bits", "8",
                 "--perturb-analytic", "1.05")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "DIVERGED" in r.stdout
