"""Regression gate (`repro.obs.regress`) + the PR-7 subsystem counters.

Two halves:

* gate semantics over fixture sidecar pairs — identical runs pass, an
  injected ``transfer/cycles`` inflation fails, in-tolerance wall-clock
  drift passes, series missing from the baseline warn instead of failing;
* the new ``kernels/`` / ``collectives/`` / ``ckpt/`` / ``data/``
  instrumentation records analytically-expected values on small inputs.
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.obs import regress
from repro.obs.regress import Delta, compare, flatten_series


# ---------------------------------------------------------------------------
# fixture sidecars
# ---------------------------------------------------------------------------

def _sidecar(tmp_path, name, mutate=None):
    """Write a small but representative sidecar; mutate(doc) edits it."""
    with obs.enabled_scope() as (reg, tr):
        for pat, cyc in [("minimal", 700), ("bbox", 300), ("mars", 200),
                         ("mars_pack", 150), ("mars_comp", 100)]:
            obs.counter_inc("transfer/cycles", cyc, pattern=pat,
                            bench="jacobi-1d", tile="6x6", dtype="fixed18")
        obs.counter_inc("kernels/hbm_bytes", 4096, kernel="pack", dir="read")
        obs.counter_inc("collectives/wire_bytes", 9216, bits=8)
        obs.hist_observe("compression/ratio", 5.0, dtype="fixed18")
        obs.hist_observe("ckpt/save_ms", 10.0)
        obs.gauge_set("train/loss", 3.0, arch="t")
        path = obs.write_sidecar(str(tmp_path / name), reg, tr,
                                 meta={"config": "fixture"})
    if mutate is not None:
        doc = json.load(open(path))
        mutate(doc)
        json.dump(doc, open(path, "w"))
    return str(tmp_path / name)


def test_gate_passes_on_identical_runs(tmp_path, capsys):
    base = _sidecar(tmp_path, "base")
    run = _sidecar(tmp_path, "run")
    assert regress.main([run, "--baseline", base]) == 0
    assert "0 regression(s)" in capsys.readouterr().out


def test_gate_fails_on_inflated_transfer_cycles(tmp_path, capsys):
    base = _sidecar(tmp_path, "base")

    def inflate(doc):
        c = doc["metrics"]["counters"]
        k = next(k for k in c if k.startswith("transfer/cycles")
                 and "mars_comp" in k)
        c[k] = c[k] * 2

    run = _sidecar(tmp_path, "run", mutate=inflate)
    assert regress.main([run, "--baseline", base]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "mars_comp" in out


def test_gate_fails_on_compression_ratio_drop(tmp_path):
    base = _sidecar(tmp_path, "base")

    def drop(doc):
        h = doc["metrics"]["histograms"]
        k = next(k for k in h if k.startswith("compression/ratio"))
        h[k]["mean"] = h[k]["mean"] * 0.5  # ratio is higher-better

    run = _sidecar(tmp_path, "run", mutate=drop)
    assert regress.main([run, "--baseline", base]) == 1


def test_wall_clock_drift_within_band_passes(tmp_path):
    base = _sidecar(tmp_path, "base")

    def slower(doc):
        doc["metrics"]["histograms"]["ckpt/save_ms"]["mean"] = 25.0  # 2.5x

    run = _sidecar(tmp_path, "run", mutate=slower)
    assert regress.main([run, "--baseline", base]) == 0
    # but beyond the band it fails
    assert regress.main([run, "--baseline", base, "--wall-tol", "0.5"]) == 1


def test_missing_baseline_series_warns_not_fails(tmp_path, capsys):
    base = _sidecar(tmp_path, "base")

    def extra(doc):
        doc["metrics"]["counters"]["kernels/hbm_bytes{kernel=new}"] = 1

    run = _sidecar(tmp_path, "run", mutate=extra)
    assert regress.main([run, "--baseline", base]) == 0
    out = capsys.readouterr().out
    assert "1 new" in out and "no baseline series" in out


def test_series_vanished_from_run_warns_not_fails(tmp_path, capsys):
    def extra(doc):
        doc["metrics"]["counters"]["kernels/hbm_bytes{kernel=old}"] = 7

    base = _sidecar(tmp_path, "base", mutate=extra)
    run = _sidecar(tmp_path, "run")
    assert regress.main([run, "--baseline", base]) == 0
    assert "1 missing" in capsys.readouterr().out


def test_gate_json_format(tmp_path, capsys):
    base = _sidecar(tmp_path, "base")
    run = _sidecar(tmp_path, "run")
    assert regress.main([run, "--baseline", base, "--format", "json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["exit_code"] == 0 and doc["stats"]["regressions"] == 0
    assert any(d["key"].startswith("transfer/cycles") for d in doc["deltas"])


def test_improvement_reports_but_passes(tmp_path, capsys):
    base = _sidecar(tmp_path, "base")

    def faster(doc):
        c = doc["metrics"]["counters"]
        k = next(k for k in c if k.startswith("transfer/cycles"))
        c[k] = c[k] // 2

    run = _sidecar(tmp_path, "run", mutate=faster)
    assert regress.main([run, "--baseline", base]) == 0
    out = capsys.readouterr().out
    assert "improved" in out and "refresh" in out


def test_compare_policy_unit():
    base = {"transfer/cycles{p=a}": {"kind": "counter", "value": 100},
            "misc/thing": {"kind": "counter", "value": 5}}
    cur = {"transfer/cycles{p=a}": {"kind": "counter", "value": 100},
           "misc/thing": {"kind": "counter", "value": 50}}
    by_key = {d.key: d for d in compare(base, cur)}
    assert by_key["transfer/cycles{p=a}"].status == "ok"
    # untracked series never fail, however wild the swing
    assert by_key["misc/thing"].status == "untracked"
    assert not any(d.failed for d in by_key.values())


# ---------------------------------------------------------------------------
# kernels/ instrumentation
# ---------------------------------------------------------------------------

def test_kernel_codec_counters_expected_values():
    from repro.kernels import ops
    q = jnp.asarray(np.arange(8 * 128).reshape(8, 128) % 50, jnp.int32)
    with obs.enabled_scope() as (reg, tr):
        planes = ops.pack_codes(q, 8, use_pallas="ref")
        q2 = ops.unpack_codes(planes, 8, 128, use_pallas="ref")
    assert bool((q == q2).all())
    lb = dict(kernel="pack", mode="ref", bits=8)
    assert reg.counter_value("kernels/hbm_bytes", dir="read",
                             **lb) == 8 * 128 * 4
    assert reg.counter_value("kernels/hbm_bytes", dir="write",
                             **lb) == 8 * (128 // 32 * 8) * 4
    assert reg.counter_value("kernels/beats", dir="read",
                             **lb) == 8 * 128 * 4 // ops.BEAT_BYTES
    ulb = dict(kernel="unpack", mode="ref", bits=8)
    assert reg.counter_value("kernels/hbm_bytes", dir="read", **ulb) == 1024
    assert reg.counter_value("kernels/hbm_bytes", dir="write", **ulb) == 4096
    assert reg.counter_value("kernels/calls", **lb) == 1
    names = [r.name for r in tr.records]
    assert "kernels/pack" in names and "kernels/unpack" in names


def test_kernel_kv_counters_expected_values():
    from repro.kernels import ops
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)),
                    jnp.float32)
    with obs.enabled_scope() as (reg, _):
        codes, scales = ops.kv_quant(x, bits=8, use_pallas="ref")
        ops.kv_dequant(codes, scales, bits=8, use_pallas="ref")
    qlb = dict(kernel="kv_quant", mode="ref", bits=8)
    assert reg.counter_value("kernels/hbm_bytes", dir="read",
                             **qlb) == 8 * 128 * 4
    assert reg.counter_value("kernels/hbm_bytes", dir="write",
                             **qlb) == 8 * 128 + 8 * 4
    dlb = dict(kernel="kv_dequant", mode="ref", bits=8)
    assert reg.counter_value("kernels/hbm_bytes", dir="read",
                             **dlb) == 8 * 128 + 8 * 4
    assert reg.counter_value("kernels/hbm_bytes", dir="write",
                             **dlb) == 8 * 128 * 4


def test_kernel_jacobi_counters_and_disabled_noop():
    from repro.kernels import ops
    x = jnp.asarray(np.random.default_rng(0).standard_normal(512),
                    jnp.float32)
    with obs.enabled_scope() as (reg, _):
        ops.jacobi1d_tiled(x, 4, width=256, use_pallas="ref")
    lb = dict(kernel="jacobi1d", mode="ref", t_steps=4)
    assert reg.counter_value("kernels/hbm_bytes", dir="read",
                             **lb) == 512 * 4
    assert reg.counter_value("kernels/hbm_bytes", dir="write",
                             **lb) == 512 * 4
    obs.disable()
    ops.jacobi1d_tiled(x, 4, width=256, use_pallas="ref")
    assert obs.instrument.registry().counter_value(
        "kernels/hbm_bytes", dir="read", **lb) == 0


# ---------------------------------------------------------------------------
# collectives/ instrumentation
# ---------------------------------------------------------------------------

def test_exchange_stats_expected_bytes():
    from repro.distributed import collectives as C
    tree = {"w": jnp.zeros((64, 128), jnp.float32),
            "b": jnp.zeros(7, jnp.float32)}
    st = C.exchange_stats(tree, bits=8)
    assert st.compressed_leaves == 1 and st.raw_leaves == 1
    assert st.raw_bytes == 64 * 128 * 4 + 7 * 4
    # planes: size*bits/8; scales: one f32 per 32-block; raw leaf verbatim
    assert st.wire_bytes == 64 * 128 + 64 * 128 // 32 * 4 + 7 * 4
    assert st.reduction == pytest.approx(st.raw_bytes / st.wire_bytes)
    with obs.enabled_scope() as (reg, _):
        st.publish(n=8192)
    assert reg.counter_value("collectives/wire_bytes", bits=8,
                             n=8192) == st.wire_bytes
    assert reg.counter_value("collectives/raw_bytes", bits=8,
                             n=8192) == st.raw_bytes
    assert reg.counter_value("collectives/leaves", kind="raw_fallback",
                             bits=8, n=8192) == 1
    assert reg.counter_value("collectives/leaves", kind="compressed",
                             bits=8, n=8192) == 1


def test_exchange_stats_matches_wire_model():
    from repro.distributed import collectives as C
    n = 1 << 14
    tree = {"w": jnp.zeros((n // 128, 128), jnp.float32)}
    for bits in (4, 8):
        st = C.exchange_stats(tree, bits)
        assert st.wire_bytes == pytest.approx(
            n * C.compressed_bytes_per_param(bits))


# ---------------------------------------------------------------------------
# ckpt/ instrumentation
# ---------------------------------------------------------------------------

def test_ckpt_counters_on_save_restore(tmp_path):
    from repro.checkpoint.ckpt import CheckpointManager
    tree = {"a": np.arange(6, dtype=np.float32),
            "b": np.ones((2, 3), np.float32)}
    nbytes = 6 * 4 + 6 * 4
    with obs.enabled_scope() as (reg, tr):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(3, tree, extra={"k": 1})
        restored, extra = mgr.restore(3, tree)
    assert extra == {"k": 1}
    assert np.array_equal(restored["a"], tree["a"])
    assert reg.counter_value("ckpt/saves") == 1
    assert reg.counter_value("ckpt/restores") == 1
    assert reg.counter_value("ckpt/bytes_written") == nbytes
    assert reg.counter_value("ckpt/bytes_read") == nbytes
    assert reg.counter_value("ckpt/leaves", op="save") == 2
    assert reg.counter_value("ckpt/leaves", op="restore") == 2
    assert reg.counter_value("ckpt/shards", op="save") >= 2
    snap = reg.snapshot().to_dict()
    assert snap["histograms"]["ckpt/save_ms"]["count"] == 1
    assert snap["histograms"]["ckpt/restore_ms"]["count"] == 1
    names = [r.name for r in tr.records]
    assert "ckpt/save" in names and "ckpt/restore" in names


def test_ckpt_async_save_records_after_wait(tmp_path):
    from repro.checkpoint.ckpt import CheckpointManager
    tree = {"a": np.zeros(4, np.float32)}
    with obs.enabled_scope() as (reg, _):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, tree)
        mgr.wait()
        assert reg.counter_value("ckpt/saves") == 1
        assert reg.counter_value("ckpt/bytes_written") == 16


# ---------------------------------------------------------------------------
# data/ instrumentation
# ---------------------------------------------------------------------------

def test_pipeline_counters():
    from repro.configs import base
    from repro.data.pipeline import SyntheticPipeline
    cfg = base.load_smoke("tinyllama-1.1b")
    rc = base.RunConfig(seq_len=32, global_batch=4, kind="train")
    with obs.enabled_scope() as (reg, _):
        p = SyntheticPipeline(cfg, rc, seed=0)
        b = p.next()
        p.next()
    want = sum(np.asarray(v).nbytes for v in b.values())
    assert reg.counter_value("data/batches", arch=cfg.name) == 2
    assert reg.counter_value("data/bytes", arch=cfg.name) == 2 * want
    snap = reg.snapshot().to_dict()
    key = f"data/batch_ms{{arch={cfg.name}}}"
    assert snap["histograms"][key]["count"] == 2


def test_pipeline_stream_identical_with_obs_off_and_on():
    from repro.configs import base
    from repro.data.pipeline import SyntheticPipeline
    cfg = base.load_smoke("tinyllama-1.1b")
    rc = base.RunConfig(seq_len=16, global_batch=2, kind="train")
    obs.disable()
    off = SyntheticPipeline(cfg, rc, seed=3).next()
    with obs.enabled_scope():
        on = SyntheticPipeline(cfg, rc, seed=3).next()
    assert np.array_equal(off["tokens"], on["tokens"])


# ---------------------------------------------------------------------------
# report hardening + shared json view
# ---------------------------------------------------------------------------

def test_report_renders_na_for_empty_run(tmp_path, capsys):
    from repro.obs import report
    with obs.enabled_scope() as (reg, tr):
        obs.write_sidecar(str(tmp_path), reg, tr, meta={})
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    assert "n/a — no transfer/cycles" in out
    assert "n/a — no spans" in out


def test_report_tolerates_partial_histograms(tmp_path, capsys):
    from repro.obs import report
    doc = {"meta": {}, "metrics": {"counters": {},
                                   "histograms": {"weird/h": {}}},
           "spans": [{"name": "s"}]}
    p = tmp_path / "BENCH_obs.json"
    p.write_text(json.dumps(doc))
    report.main([str(p)])
    out = capsys.readouterr().out
    assert "weird/h" in out and "n/a" in out


def test_report_json_matches_gate_view(tmp_path, capsys):
    from repro.obs import report
    with obs.enabled_scope() as (reg, tr):
        obs.counter_inc("transfer/cycles", 42, pattern="mars_comp")
        obs.hist_observe("ckpt/save_ms", 7.0)
        obs.write_sidecar(str(tmp_path), reg, tr, meta={"config": "t"})
    report.main([str(tmp_path), "--format=json"])
    doc = json.loads(capsys.readouterr().out)
    assert doc["series"]["transfer/cycles{pattern=mars_comp}"] == \
        {"kind": "counter", "value": 42}
    assert doc["series"]["ckpt/save_ms"]["value"] == 7.0
    # same numbers the gate compares
    sidecar = json.load(open(tmp_path / "BENCH_obs.json"))
    assert doc["series"] == flatten_series(sidecar)
