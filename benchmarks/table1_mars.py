"""Paper Table 1: benchmark characteristics — #MARS in/out, burst counts.

Validates that the MARS extraction + layout ILP reproduce the published
numbers exactly, and that they are independent of tile size.
"""
from repro.core import layout, mars, stencil

# one source of truth for the (benchmark, tile-size) grid: the zoo is
# shared with repro.analysis' layout-invariant pass and the test suite
ROWS = [(name, list(tiles)) for name, tiles in stencil.ZOO.items()]

PAPER = {
    "jacobi-1d": (7, 4, 3, 1),
    "jacobi-2d": (28, 13, 10, 1),
    "seidel-2d": (33, 13, 10, 1),
}


def run():
    print("benchmark,tile,mars_in,mars_out,read_bursts,write_bursts,"
          "paper_match")
    results = []
    for name, tiles in ROWS:
        for ts in tiles:
            spec = stencil.SPECS[name](ts)
            a = mars.analyze(spec)
            lr = layout.layout_for_analysis(a)
            row = (a.n_in, a.n_out, lr.read_bursts, lr.write_bursts)
            match = row == PAPER[name]
            tile_s = "x".join(map(str, ts))
            print(f"{name},{tile_s},{row[0]},{row[1]},{row[2]},{row[3]},"
                  f"{match}")
            results.append((name, ts, row, match))
    assert all(m for *_, m in results), "Table 1 mismatch"
    return results


if __name__ == "__main__":
    run()
