"""Beyond-paper: codec + executor hot-path microbenchmark.

Times the vectorized §2.5 differential codec (``compress_words`` /
``decompress_words``) against the retained scalar reference implementation
(``*_ref``, the seed's per-word bignum model) on smooth stencil data, and
the tiled MARS executor end to end.  Published series (see
``src/repro/obs/README.md`` for the gate policy):

* ``codec/words{dtype,op}``   — words processed (logical, gated exact)
* ``codec/bits{dtype}``       — compressed stream size (logical, gated exact)
* ``codec/bench_ms{...}``     — wall time per dtype x op x impl
* ``codec/words_per_s{...}``  — throughput gauges (wall-banded in the gate)
* ``exec/tiles_per_s{...}``   — executor throughput; the ``exec/*`` counters
  themselves are published by the executor at the end of ``run``

The fast path must stay >= ``SPEEDUP_FLOOR`` x the reference on the smoke
grid — that is this PR's acceptance bar, asserted on every run.
"""
import time

import numpy as np

from repro import obs
from repro.core import compression as comp
from repro.core import stencil
from repro.core.executor import Jacobi1dMarsExecutor

#: required fast-vs-reference throughput ratio on the smoke grid
SPEEDUP_FLOOR = 10.0

#: words per stream — fixed across smoke/full so codec/words, codec/bits
#: baselines stay comparable between the two modes
N_WORDS = 1 << 15

SMOKE_DTYPES = ["fixed18", "float"]


def _stream_words(dtype: str) -> tuple:
    """Smooth jacobi-style data -> (codec words, nbits) for one dtype."""
    rng = np.random.default_rng(0)
    vals = np.cumsum(rng.uniform(-0.01, 0.01, N_WORDS)) + 1.0
    return comp.words_for(vals, dtype)


def _best_ms(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def run(smoke: bool = False):
    dtypes = SMOKE_DTYPES if smoke else list(comp.DATA_TYPES)
    reps = 1 if smoke else 3
    print("dtype,op,ref_ms,fast_ms,speedup,fast_words_per_s")
    out = []
    for dt in dtypes:
        words, nbits = _stream_words(dt)
        fast_w = comp.BitWriter()
        comp.compress_words(words, nbits, fast_w)
        bits = fast_w.bit_length
        stream = fast_w.to_words(32)
        obs.counter_inc("codec/bits", bits, dtype=dt)

        def c_ref():
            w = comp.ReferenceBitWriter()
            comp.compress_words_ref(words, nbits, w)

        def c_fast():
            w = comp.BitWriter()
            comp.compress_words(words, nbits, w)

        def d_ref():
            r = comp.ReferenceBitReader(stream, bits, 32)
            comp.decompress_words_ref(r, len(words), nbits)

        def d_fast():
            r = comp.BitReader(stream, bits, 32)
            comp.decompress_words(r, len(words), nbits)

        for op, ref_fn, fast_fn in (("compress", c_ref, c_fast),
                                    ("decompress", d_ref, d_fast)):
            with obs.span("codec/bench", dtype=dt, op=op):
                ref_ms = _best_ms(ref_fn, reps)
                fast_ms = _best_ms(fast_fn, reps)
            speedup = ref_ms / fast_ms
            wps = len(words) / (fast_ms * 1e-3)
            obs.counter_inc("codec/words", len(words), dtype=dt, op=op)
            for impl, ms in (("ref", ref_ms), ("fast", fast_ms)):
                obs.gauge_set("codec/bench_ms", ms, dtype=dt, op=op,
                              impl=impl)
            obs.gauge_set("codec/words_per_s", wps, dtype=dt, op=op)
            print(f"{dt},{op},{ref_ms:.2f},{fast_ms:.2f},"
                  f"{speedup:.1f},{wps:.3g}")
            out.append((dt, op, ref_ms, fast_ms, speedup))

    # executor throughput: full MARS pipeline (read/decompress/execute/
    # compress/write) over a small seeded jacobi-1d run
    rng = np.random.default_rng(3)
    n, tsteps = 160, 48
    init = np.cumsum(rng.uniform(-0.005, 0.005, n)) + 0.5
    ex = Jacobi1dMarsExecutor(stencil.jacobi1d_spec((6, 6)), n, tsteps,
                              dtype="fixed18")
    t0 = time.perf_counter()
    ex.run(init)
    dt_s = time.perf_counter() - t0
    tiles = ex.stats.full_tiles + ex.stats.host_tiles
    tps = tiles / dt_s
    obs.gauge_set("exec/tiles_per_s", tps, bench="jacobi-1d", dtype="fixed18")
    print(f"# executor: {tiles} tiles in {dt_s * 1e3:.1f} ms "
          f"({tps:.0f} tiles/s)")

    floor = min(s for d, _, _, _, s in out if d in SMOKE_DTYPES)
    print(f"# min fast-vs-ref speedup on smoke grid: {floor:.1f}x "
          f"(floor: {SPEEDUP_FLOOR:.0f}x)")
    assert floor >= SPEEDUP_FLOOR, (
        f"vectorized codec only {floor:.1f}x the reference "
        f"(required >= {SPEEDUP_FLOOR}x)")
    return out


if __name__ == "__main__":
    run()
