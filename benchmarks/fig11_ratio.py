"""Paper Fig. 11: compression ratio vs data type and tile size (jacobi-1d).

Reports the *true ratio* (codec savings only) and the *ratio with padding*
(what the accelerator actually gains, uncompressed data being padded to bus
alignment).  Paper peak: 5.09:1 for 18-bit fixed at 200x200 tiles.

The paper does not print its fixed-point Q format.  Two series are reported:
``max-precision`` (frac = nbits-2, every representable bit used) and
``paper-matched`` (8 integer bits, the format family under which the
published 5.09:1 peak is reproduced on PolyBench-style smooth data — Jacobi
data deltas quantize to <=1 ulp there).
"""
import numpy as np

from repro import obs
from repro.core import compression as comp
from repro.core import layout, mars, packing, stencil, transfer

DTYPES = ["fixed12", "fixed18", "fixed24", "fixed28", "float", "double"]
TILES = [(6, 6), (64, 64), (200, 200)]
SMOKE_DTYPES = ["fixed18", "float"]
SMOKE_TILES = [(6, 6), (64, 64)]
#: paper-matched Q format: 8 integer bits (PolyBench jacobi data is O(1))
MATCHED_FRAC = {"fixed12": 4, "fixed18": 10, "fixed24": 16, "fixed28": 20}


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    # PolyBench jacobi-1d init is the linear ramp (i + 2) / n
    n = 4000
    init = (np.arange(n) + 2.0) / n + rng.uniform(-5e-5, 5e-5, n)
    hist = stencil.jacobi1d_reference(init, 700)
    print("tile,dtype,format,true_ratio,ratio_with_padding")
    out = []
    dtypes = SMOKE_DTYPES if smoke else DTYPES
    for ts in (SMOKE_TILES if smoke else TILES):
        spec = stencil.SPECS["jacobi-1d"](ts)
        a = mars.analyze(spec)
        lr = layout.layout_for_analysis(a)
        rep = tuple(int(x) for x in spec.tile_of(
            np.array([[hist.shape[0] // 2, 2000]]))[0])
        m = transfer.TileIOModel(spec, a, lr, rep_tile=rep)
        for dt in dtypes:
            nbits, _ = packing.dtype_widths(dt)
            formats = [("maxprec", None)]
            if dt in MATCHED_FRAC:
                formats.append(("matched", MATCHED_FRAC[dt]))
            for label, frac in formats:
                count, bits = 0, 0
                for pts in m.output_mars_points():
                    vals = stencil.stencil_values("jacobi-1d", hist, pts)
                    if dt.startswith("fixed"):
                        words = comp.quantize_fixed(vals, nbits, frac)
                        nb = nbits
                    else:
                        words, nb = comp.words_for(vals, dt)
                    bits += comp.compressed_cost_bits(words, nb)
                    count += len(vals)
                r = packing.compression_ratios(count, nbits, bits)
                tile_s = "x".join(map(str, ts))
                obs.hist_observe("compression/ratio", r.true_ratio,
                                 dtype=dt, fmt=label, tile=tile_s)
                obs.hist_observe("compression/ratio_padded",
                                 r.ratio_with_padding,
                                 dtype=dt, fmt=label, tile=tile_s)
                print(f"{tile_s},{dt},{label},{r.true_ratio:.2f},"
                      f"{r.ratio_with_padding:.2f}")
                out.append((ts, dt, label, r))
    # paper observations: large tiles compress better; fixed18 at 200x200
    # reaches ~5:1 with padding (under the matched format)
    big = SMOKE_TILES[-1] if smoke else (200, 200)
    best18 = max(r.ratio_with_padding for ts, dt, lb, r in out
                 if dt == "fixed18" and ts == big)
    small18 = max(r.ratio_with_padding for ts, dt, lb, r in out
                  if dt == "fixed18" and ts == (6, 6))
    print(f"# fixed18 {'x'.join(map(str, big))} best ratio w/ padding: "
          f"{best18:.2f} (paper: 5.09 at 200x200); 6x6 best: {small18:.2f}")
    assert best18 > small18, "large tiles must compress better"
    if not smoke:
        assert best18 > 4.0, "paper's ~5:1 regime not reached"
    return out


if __name__ == "__main__":
    run()
