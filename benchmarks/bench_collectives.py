"""Beyond-paper: compressed cross-pod gradient exchange — wire-byte savings
and wall-time of the codec itself (CPU timing; wire model analytical).

Mirrors how the paper's packing/compression reduce transferred bits: the
cross-pod link carries packed bitplanes + scale markers instead of raw f32.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import blockcodec as bc
from repro.distributed.collectives import compressed_bytes_per_param

SIZES = [1 << 16, 1 << 20, 1 << 22]
BITS = [4, 6, 8, 16]


def run():
    print("n_values,bits,wire_bytes_per_param,reduction_vs_f32,"
          "codec_us_per_mb")
    for n in SIZES:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        for bits in BITS:
            cfg = bc.BlockCodecConfig(bits=bits, block=256, delta=False)
            f = jax.jit(lambda v: bc.compress(v, cfg))
            planes, scale = f(x)
            jax.block_until_ready(planes)
            t0 = time.perf_counter()
            for _ in range(3):
                planes, scale = f(x)
            jax.block_until_ready(planes)
            dt = (time.perf_counter() - t0) / 3
            wire = compressed_bytes_per_param(bits)
            print(f"{n},{bits},{wire:.3f},{4.0 / wire:.2f},"
                  f"{dt * 1e6 / (n * 4 / 1e6):.1f}")


if __name__ == "__main__":
    run()
