"""Beyond-paper: compressed cross-pod gradient exchange — wire-byte savings
and wall-time of the codec itself (CPU timing; wire model analytical).

Mirrors how the paper's packing/compression reduce transferred bits: the
cross-pod link carries packed bitplanes + scale markers instead of raw f32.

Publishes ``collectives/wire_bytes{bits=...}`` / ``collectives/raw_bytes``
/ ``collectives/leaves{kind=...}`` via ``ExchangeStats.publish`` on a small
synthetic gradient tree (one compressible matrix + one raw-fallback norm
vector per size), so the regression gate tracks the wire model per PR.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import blockcodec as bc
from repro.distributed.collectives import (compressed_bytes_per_param,
                                           exchange_stats)

SIZES = [1 << 16, 1 << 20, 1 << 22]
BITS = [4, 6, 8, 16]

#: CI-safe subset: one size, the paper-relevant bit widths
SMOKE_SIZES = [1 << 16]
SMOKE_BITS = [4, 8]


def _grad_tree(n: int) -> dict:
    """One compressible matrix leaf + one tiny raw-fallback leaf."""
    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.standard_normal((n // 128, 128)), jnp.float32),
        "norm_scale": jnp.asarray(rng.standard_normal(7), jnp.float32),
    }


def run(smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES
    bits_grid = SMOKE_BITS if smoke else BITS
    reps = 1 if smoke else 3
    print("n_values,bits,wire_bytes_per_param,reduction_vs_f32,"
          "codec_us_per_mb")
    for n in sizes:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        tree = _grad_tree(n)
        for bits in bits_grid:
            cfg = bc.BlockCodecConfig(bits=bits, block=256, delta=False)
            f = jax.jit(lambda v: bc.compress(v, cfg))
            planes, scale = f(x)
            jax.block_until_ready(planes)
            t0 = time.perf_counter()
            for _ in range(reps):
                planes, scale = f(x)
            jax.block_until_ready(planes)
            dt = (time.perf_counter() - t0) / reps
            wire = compressed_bytes_per_param(bits)
            # wire accounting for the exchange of the synthetic grad tree:
            # the gate tracks these exact byte counts per (n, bits)
            exchange_stats(tree, bits).publish(n=n)
            print(f"{n},{bits},{wire:.3f},{4.0 / wire:.2f},"
                  f"{dt * 1e6 / (n * 4 / 1e6):.1f}")


if __name__ == "__main__":
    run()
