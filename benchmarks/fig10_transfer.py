"""Paper Fig. 10: per-tile transfer cycles relative to compressed MARS.

For each benchmark x data type, the per-tile I/O cycles of each access
pattern (minimal / bbox / mars / mars_pack) are reported relative to
mars_comp (lower-is-better in the paper; here ratio>1 means slower than
compressed MARS).  Stencil data comes from a real simulation so compressed
sizes are genuine.
"""
import numpy as np

from repro import obs
from repro.core import layout, mars, stencil, transfer

CASES = [
    ("jacobi-1d", (64, 64), ["fixed18", "fixed24", "float"]),
    ("jacobi-1d", (200, 200), ["fixed18", "float"]),
    ("jacobi-2d", (4, 5, 7), ["fixed18", "float"]),
    ("seidel-2d", (4, 10, 10), ["fixed18", "float"]),
]

#: CI-safe subset: one benchmark, all five access patterns still exercised
SMOKE_CASES = [
    ("jacobi-1d", (64, 64), ["fixed18", "float"]),
    ("jacobi-2d", (4, 5, 7), ["float"]),
]


def _history(name, spec):
    rng = np.random.default_rng(0)
    if name == "jacobi-1d":
        init = np.cumsum(rng.uniform(-0.01, 0.01, 4000)) + 1.0
        return stencil.jacobi1d_reference(init, 500)
    n, t = 160, 40
    init = np.cumsum(np.cumsum(rng.uniform(-1e-3, 1e-3, (n, n)), 0), 1) + 1.0
    if name == "jacobi-2d":
        return stencil.jacobi2d_reference(init, t)
    return stencil.seidel2d_reference(init[:64, :64], 16)


def _interior_tile(spec, hist, name):
    """A representative tile whose points (and producers) are in-domain."""
    if name == "jacobi-1d":
        p = np.array([[hist.shape[0] // 2, hist.shape[1] // 2]])
    elif name == "jacobi-2d":
        t = hist.shape[0] // 2
        i = hist.shape[1] // 2
        p = np.array([[t, i + t, i + t]])
    else:
        t = max(hist.shape[0] // 2 - 1, 2)
        i = hist.shape[1] // 2
        p = np.array([[t, i + 2 * t, 3 * t + 2 * i + i]])
    return tuple(int(x) for x in spec.tile_of(p)[0])


def run(smoke: bool = False):
    print("benchmark,tile,dtype,minimal,bbox,mars,mars_pack,mars_comp_cycles")
    out = []
    for name, ts, dtypes in (SMOKE_CASES if smoke else CASES):
        spec = stencil.SPECS[name](ts)
        a = mars.analyze(spec)
        lr = layout.layout_for_analysis(a)
        hist = _history(name, spec)
        rep = _interior_tile(spec, hist, name)
        m = transfer.TileIOModel(spec, a, lr, rep_tile=rep)
        for dt in dtypes:
            with obs.span("fig10/tile_io", bench=name, dtype=dt):
                # tile_io publishes transfer/cycles{pattern=...} counters
                # itself when obs is enabled (repro.core.transfer)
                cyc = {mode: m.tile_io(dt, mode, hist=hist).total_cycles
                       for mode in transfer.MODES}
            base = cyc["mars_comp"]
            tile_s = "x".join(map(str, ts))
            print(f"{name},{tile_s},{dt},"
                  f"{cyc['minimal'] / base:.2f},{cyc['bbox'] / base:.2f},"
                  f"{cyc['mars'] / base:.2f},{cyc['mars_pack'] / base:.2f},"
                  f"{base}")
            out.append((name, ts, dt, cyc))
    # headline claim: the paper reports up to 7x vs un-optimized accesses.
    # The seed repo reproduced that number only through a lexsort-key bug in
    # core/transfer._runs that never coalesced contiguous runs within a row,
    # inflating the minimal baseline; with the corrected HLS-style model the
    # honest grid peak is lower (minimal coalesces what it can).
    best = max(c["minimal"] / c["mars_comp"] for *_, c in out)
    best_unopt = max(max(c["minimal"], c["bbox"]) / c["mars_comp"]
                     for *_, c in out)
    print(f"# max I/O-cycle reduction vs minimal: {best:.1f}x; vs worst "
          f"un-optimized pattern: {best_unopt:.1f}x (paper: up to 7x against "
          f"an uncoalesced baseline)")
    obs.gauge_set("fig10/max_cycle_reduction", best)
    obs.gauge_set("fig10/max_cycle_reduction_unopt", best_unopt)
    if not smoke:  # the smoke subset omits the 2D cases with the best gains
        assert best >= 2.5, best
        assert best_unopt >= 3.5, best_unopt
    return out


if __name__ == "__main__":
    run()
