"""Beyond-paper: packed KV-cache — decode memory-roofline effect per arch.

For each attention arch's decode_32k cell: KV bytes/step read at bf16 vs
packed int8/int4 (+ scale markers), and the resulting memory-term change
(decode reads the whole cache every step, so bytes ~ = the memory term).
"""
from repro.configs import base
from repro.launch.roofline import HBM_BW

ARCHS = ["tinyllama-1.1b", "qwen1.5-110b", "yi-9b", "granite-8b",
         "grok-1-314b", "mixtral-8x7b", "internvl2-76b", "hymba-1.5b"]


def cache_bytes(cfg, rc, bits):
    """Total cache bytes: codes + per-(pos, head) f32 scale markers."""
    s = rc.seq_len if not cfg.sliding_window else min(rc.seq_len,
                                                      cfg.sliding_window)
    per_pos = cfg.n_kv_heads * cfg.hd * bits // 8
    if bits != 16:
        per_pos += cfg.n_kv_heads * 4          # scale marker per head row
    return rc.global_batch * cfg.n_layers * 2 * s * per_pos


def run():
    print("arch,cache_GB_bf16,cache_GB_int8,cache_GB_int4,"
          "mem_term_ms_bf16_256chips,mem_term_ms_int8")
    for arch in ARCHS:
        cfg = base.load_arch(arch)
        rc = base.run_config_for("decode_32k", cfg)
        b16 = cache_bytes(cfg, rc, 16)
        b8 = cache_bytes(cfg, rc, 8)
        b4 = cache_bytes(cfg, rc, 4)
        t16 = b16 / 256 / HBM_BW * 1e3
        t8 = b8 / 256 / HBM_BW * 1e3
        print(f"{arch},{b16 / 1e9:.2f},{b8 / 1e9:.2f},{b4 / 1e9:.2f},"
              f"{t16:.2f},{t8:.2f}")


if __name__ == "__main__":
    run()
