"""Benchmark aggregator: one section per paper table/figure + beyond-paper.

``python -m benchmarks.run``
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.parse_args()

    from benchmarks import (bench_collectives, bench_kvcache,
                            bench_stencil_kernel, fig10_transfer, fig11_ratio,
                            table1_mars, table2_compile)

    sections = [
        ("Table 1 — MARS & burst counts", table1_mars.run),
        ("Table 2 — layout + analysis time", table2_compile.run),
        ("Fig 10 — transfer cycles by access pattern", fig10_transfer.run),
        ("Fig 11 — compression ratio vs dtype x tile", fig11_ratio.run),
        ("Beyond-paper: compressed collectives", bench_collectives.run),
        ("Beyond-paper: packed KV cache", bench_kvcache.run),
        ("Beyond-paper: irredundant stencil kernel", bench_stencil_kernel.run),
    ]
    failures = []
    for title, fn in sections:
        print(f"\n=== {title} ===")
        t0 = time.time()
        try:
            fn()
            print(f"[ok in {time.time() - t0:.1f}s]")
        except Exception as e:  # pragma: no cover
            failures.append((title, e))
            print(f"[FAILED: {e}]")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
