"""Benchmark aggregator: one section per paper table/figure + beyond-paper.

``python -m benchmarks.run [--smoke] [--out benchmarks/out] [--seed 0]``

Every run is reproducible and attributable:

* all RNG is seeded explicitly (``--seed`` feeds ``numpy`` global state and
  ``random``; the sections themselves use fixed ``default_rng`` seeds);
* ``<out>/BENCH.json`` records per-section status/duration plus run
  metadata — git SHA, dirty flag, config name, seed, argv;
* ``<out>/BENCH_obs.json`` is the observability sidecar
  (``repro.obs.sink.write_sidecar``): every ``transfer/cycles``,
  ``compression/ratio``, ... series the sections emitted, renderable with
  ``python -m repro.obs.report <out>``.

``--smoke`` is the CI-safe mode: every section runs with reduced case
grids (the beyond-paper benches shrink their sweeps and use the jnp ``ref``
kernel backend), a few seconds end to end — small enough for CI, complete
enough that ``python -m repro.obs.regress`` can gate the kernels /
collectives / ckpt series every PR.
"""
import argparse
import json
import os
import random
import sys
import time

import numpy as np

from repro import obs

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out")


def sections(smoke: bool):
    from benchmarks import (bench_analysis, bench_audit, bench_ckpt,
                            bench_codec, bench_collectives, bench_kvcache,
                            bench_stencil_kernel, fig10_transfer,
                            fig11_ratio, table1_mars, table2_compile)

    # every section runs in smoke mode too (reduced grids) so the
    # regression gate sees kernels/collectives/ckpt series in CI
    return [
        ("table1_mars", "Table 1 — MARS & burst counts", table1_mars.run),
        ("table2_compile", "Table 2 — layout + analysis time",
         table2_compile.run),
        ("fig10_transfer", "Fig 10 — transfer cycles by access pattern",
         lambda: fig10_transfer.run(smoke=smoke)),
        ("fig11_ratio", "Fig 11 — compression ratio vs dtype x tile",
         lambda: fig11_ratio.run(smoke=smoke)),
        ("bench_codec", "Beyond-paper: vectorized codec + executor",
         lambda: bench_codec.run(smoke=smoke)),
        ("bench_kvcache", "Beyond-paper: packed KV cache", bench_kvcache.run),
        ("bench_collectives", "Beyond-paper: compressed collectives",
         lambda: bench_collectives.run(smoke=smoke)),
        ("bench_audit", "Beyond-paper: HLO-vs-analytic byte audit",
         lambda: bench_audit.run(smoke=smoke)),
        ("bench_stencil_kernel",
         "Beyond-paper: irredundant stencil kernel",
         lambda: bench_stencil_kernel.run(smoke=smoke)),
        ("bench_ckpt", "Beyond-paper: checkpoint save/restore",
         lambda: bench_ckpt.run(smoke=smoke)),
        ("bench_analysis", "Beyond-paper: static layout/access linter",
         lambda: bench_analysis.run(smoke=smoke)),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI-safe subset (paper sections, small grids)")
    ap.add_argument("--quick", action="store_true",
                    help="deprecated alias for --smoke")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="directory for BENCH.json + BENCH_obs.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    smoke = args.smoke or args.quick

    # explicit global seeding: sections use their own default_rng(0)
    # streams, but anything reaching numpy/python global state is pinned too
    np.random.seed(args.seed)
    random.seed(args.seed)

    config_name = "smoke" if smoke else "full"
    meta = obs.run_metadata(config=config_name, seed=args.seed, smoke=smoke)

    obs.enable(obs.Registry(), obs.Tracer())
    results = []
    failures = []
    for key, title, fn in sections(smoke):
        print(f"\n=== {title} ===")
        t0 = time.time()
        try:
            with obs.span(f"bench/{key}"):
                fn()
            dt = time.time() - t0
            results.append({"section": key, "ok": True, "seconds": dt})
            print(f"[ok in {dt:.1f}s]")
        except Exception as e:  # pragma: no cover
            results.append({"section": key, "ok": False, "seconds":
                            time.time() - t0, "error": repr(e)})
            failures.append((title, e))
            print(f"[FAILED: {e}]")

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "BENCH.json"), "w") as f:
        json.dump({"meta": meta, "sections": results}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    sidecar = obs.write_sidecar(args.out, meta=meta)
    obs.write_jsonl(os.path.join(args.out, "obs.jsonl"), meta=meta)
    obs.disable()
    print(f"\nwrote {sidecar} "
          f"(render: python -m repro.obs.report {args.out})")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
