"""Beyond-paper: checkpoint save/restore latency + bytes on a synthetic tree.

Exercises the instrumented ``repro.checkpoint.ckpt`` path end to end
(atomic publish, manifest, reshard-on-load) so every smoke run records
``ckpt/save_ms`` / ``ckpt/restore_ms`` spans and byte counters for the
regression gate.  Bytes written/read are deterministic (seeded tree);
wall-clock rides the gate's percentage band.
"""
import tempfile

import numpy as np

import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager

SHAPES = {
    "embed": (256, 128),
    "layer0/w": (128, 512),
    "layer0/b": (512,),
    "head": (128, 64),
}
SMOKE_SHAPES = {
    "embed": (64, 32),
    "layer0/w": (32, 128),
    "layer0/b": (128,),
}


def _tree(shapes):
    rng = np.random.default_rng(0)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in shapes.items()}


def run(smoke: bool = False):
    shapes = SMOKE_SHAPES if smoke else SHAPES
    tree = _tree(shapes)
    total = sum(int(np.prod(s)) * 4 for s in shapes.values())
    print("leaves,bytes,save_restore_ok")
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        mgr.save(1, tree, extra={"data_step": 1})
        restored, extra = mgr.restore(1, tree)
        ok = all(bool(jnp.array_equal(tree[k], restored[k])) for k in tree)
        ok = ok and extra == {"data_step": 1} and mgr.latest_step() == 1
        print(f"{len(shapes)},{total},{ok}")
        assert ok


if __name__ == "__main__":
    run()
