"""Beyond-paper: HLO-vs-analytic audit as a gated bench section.

Runs ``python -m repro.launch.audit`` in a subprocess (the audit's wire
program needs a 2-pod mesh, so the child forces
``--xla_force_host_platform_device_count`` before importing jax; the bench
process itself stays single-device) over the same (size, bits) grid
``bench_collectives`` exchanges, then publishes the report as ``audit/*``
series via ``repro.launch.audit.publish_report`` so the regression gate
fails CI when the compiled HLO drifts from the analytic byte models.
"""
import json
import os
import subprocess
import sys
import tempfile

from repro.launch import audit

from benchmarks.bench_collectives import BITS, SIZES, SMOKE_BITS, SMOKE_SIZES


def run(smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES[:2]
    bits_grid = SMOKE_BITS if smoke else BITS[:3]
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "audit.json")
        cmd = [sys.executable, "-m", "repro.launch.audit", "--json", out,
               "--sizes", *map(str, sizes), "--bits", *map(str, bits_grid)]
        r = subprocess.run(cmd, capture_output=True, text=True)
        sys.stdout.write(r.stdout)
        if r.returncode or not os.path.exists(out):
            sys.stderr.write(r.stderr)
            raise RuntimeError(
                f"audit subprocess failed (exit {r.returncode})")
        with open(out) as f:
            report = json.load(f)
    audit.publish_report(report)
    print(f"audit: {report['n_checks']} checks, "
          f"{report['divergences']} divergence(s)")


if __name__ == "__main__":
    run()
