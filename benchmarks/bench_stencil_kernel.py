"""Chunked-jacobi Pallas macro-pipeline: irredundant carry vs overlapped halo.

Compares the HBM traffic of the kernel's irredundant scheme (carry MARS
through VMEM scratch) against conventional overlapped (trapezoidal) tiling
that re-reads a T-wide halo per chunk — the paper's irredundancy property at
kernel level.  Also times the kernel path vs the jnp reference for
correctness-path sanity (CPU times are not TPU predictions).

The instrumented ``repro.kernels.ops`` entry points publish
``kernels/hbm_bytes{kernel=jacobi1d,...}`` for every call, which the
regression gate tracks; this bench additionally publishes the analytic
overlapped-vs-irredundant model as ``kernels/halo_overhead_bytes``.

In smoke mode the grid shrinks and the kernel runs on the ``ref`` backend
(Pallas interpret mode is an order of magnitude slower and unavailable on
some jax builds); the full run keeps ``interpret`` for kernel-path sanity.
"""
import numpy as np

import jax.numpy as jnp

from repro import obs
from repro.kernels import ops, ref

CASES = [(1 << 16, 16, 512), (1 << 18, 64, 512), (1 << 18, 100, 128)]
SMOKE_CASES = [(1 << 14, 16, 512)]


def traffic_model(n, t_steps, width):
    """Bytes moved per full pass, f32."""
    irredundant = n * 4 * 2                          # read chunk + write chunk
    overlapped = (n + (n // width) * 2 * t_steps) * 4 + n * 4
    return irredundant, overlapped


def codec_roundtrip(backend: str):
    """Tiny pack/unpack + KV quant roundtrips through the instrumented
    ``ops`` entry points, so the gate also tracks the codec kernels'
    ``kernels/hbm_bytes`` series."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.integers(-60, 60, (8, 128)), jnp.int32)
    planes = ops.pack_codes(q, 8, use_pallas=backend)
    q2 = ops.unpack_codes(planes, 8, 128, use_pallas=backend)
    assert bool((q == q2).all()), "pack/unpack roundtrip mismatch"
    x = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
    codes, scales = ops.kv_quant(x, bits=8, use_pallas=backend)
    xr = ops.kv_dequant(codes, scales, bits=8, use_pallas=backend)
    assert bool(jnp.abs(x - xr).max() < 0.05), "kv roundtrip drifted"


def run(smoke: bool = False):
    backend = "ref" if smoke else "interpret"
    codec_roundtrip(backend)
    print("n,t_steps,width,irredundant_MB,overlapped_MB,saving,"
          "kernel_ok")
    for n, t, w in (SMOKE_CASES if smoke else CASES):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        y_ref = ref.jacobi_chunked_ref(x, t)
        y_k = ops.jacobi1d_tiled(x, t, width=w, use_pallas=backend)
        ok = bool(jnp.abs(y_ref - y_k).max() < 1e-4)
        ir, ov = traffic_model(n, t, w)
        obs.counter_inc("kernels/halo_overhead_bytes", ov - ir,
                        kernel="jacobi1d", n=n, t_steps=t, width=w)
        print(f"{n},{t},{w},{ir / 1e6:.2f},{ov / 1e6:.2f},"
              f"{ov / ir:.2f}x,{ok}")
        assert ok


if __name__ == "__main__":
    run()
