"""Chunked-jacobi Pallas macro-pipeline: irredundant carry vs overlapped halo.

Compares the HBM traffic of the kernel's irredundant scheme (carry MARS
through VMEM scratch) against conventional overlapped (trapezoidal) tiling
that re-reads a T-wide halo per chunk — the paper's irredundancy property at
kernel level.  Also times the interpret-mode kernel vs the jnp reference for
correctness-path sanity (CPU times are not TPU predictions).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def traffic_model(n, t_steps, width):
    """Bytes moved per full pass, f32."""
    irredundant = n * 4 * 2                          # read chunk + write chunk
    overlapped = (n + (n // width) * 2 * t_steps) * 4 + n * 4
    return irredundant, overlapped


def run():
    print("n,t_steps,width,irredundant_MB,overlapped_MB,saving,"
          "kernel_ok")
    for n, t, w in [(1 << 16, 16, 512), (1 << 18, 64, 512),
                    (1 << 18, 100, 128)]:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        y_ref = ref.jacobi_chunked_ref(x, t)
        y_k = ops.jacobi1d_tiled(x, t, width=w, use_pallas="interpret")
        ok = bool(jnp.abs(y_ref - y_k).max() < 1e-4)
        ir, ov = traffic_model(n, t, w)
        print(f"{n},{t},{w},{ir / 1e6:.2f},{ov / 1e6:.2f},"
              f"{ov / ir:.2f}x,{ok}")
        assert ok


if __name__ == "__main__":
    run()
