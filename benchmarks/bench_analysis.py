"""Beyond-paper: static analyzer as a gated bench section.

Runs the ``repro.analysis`` selftest (every rule family must fire on an
injected violation — the gate is only trustworthy if it can fail), then
the full three-pass run in-process, and publishes the ``analysis/*``
series so ``repro.obs.regress`` fails CI on any new finding even when
nobody invoked the CLI.
"""
from repro.analysis import runner


def run(smoke: bool = False):
    st = runner.selftest()
    if not st["ok"]:
        missed = [k for k, v in st["fired"].items() if not v]
        raise RuntimeError(f"analysis selftest missed: {missed}")
    # smoke skips the jax kernel-lowering pass (bench_audit already
    # compiles the same grid in its subprocess); full runs everything
    report = runner.run_all(with_access=not smoke)
    runner.publish_report(report)
    print(runner.render_report(report))
    if report["n_new"]:
        raise RuntimeError(
            f"{report['n_new']} new static-analysis finding(s)")


if __name__ == "__main__":
    run()
