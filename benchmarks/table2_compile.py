"""Paper Table 2: layout computation + code generation time.

The paper reports 0.68-5.57 s with Gurobi; our exact Held-Karp solver plus
the full MARS analysis runs in the same order (the ILP itself is
microseconds — the paper's time is dominated by its codegen, ours by the
point-based analysis).
"""
import time

from repro.core import layout, mars, stencil

ROWS = [
    ("jacobi-1d", (6, 6)), ("jacobi-1d", (64, 64)), ("jacobi-1d", (200, 200)),
    ("jacobi-2d", (4, 5, 7)), ("jacobi-2d", (10, 10, 10)),
    ("seidel-2d", (4, 10, 10)),
]


def run():
    print("benchmark,tile,analysis_s,layout_solve_s,total_s,paper_s")
    paper = {("jacobi-1d", (6, 6)): 0.76, ("jacobi-1d", (64, 64)): 0.68,
             ("jacobi-1d", (200, 200)): 1.02, ("jacobi-2d", (4, 5, 7)): 5.57,
             ("jacobi-2d", (10, 10, 10)): 5.09,
             ("seidel-2d", (4, 10, 10)): 3.21}
    out = []
    for name, ts in ROWS:
        spec = stencil.SPECS[name](ts)
        t0 = time.perf_counter()
        a = mars.analyze(spec)
        t1 = time.perf_counter()
        lr = layout.layout_for_analysis(a)
        t2 = time.perf_counter()
        tile_s = "x".join(map(str, ts))
        print(f"{name},{tile_s},{t1 - t0:.3f},{lr.solve_time_s:.4f},"
              f"{t2 - t0:.3f},{paper[(name, ts)]}")
        out.append((name, ts, t2 - t0))
    return out


if __name__ == "__main__":
    run()
