"""Batched serving engine with packed (paper-layout) KV cache.

Prompts of different lengths decode in lockstep: each sequence tracks its own
position; while a sequence is still inside its prompt the engine feeds the
next prompt token (teacher forcing), afterwards it feeds the model's argmax.
The KV cache layout is controlled by ``RunConfig.kv_cache_bits``:
16 = bf16 (padded words, the paper's baseline), 8/4 = packed int blocks with
per-row scale markers (§2.4 packing + §4.2.2 metadata).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model_zoo
from repro.obs import instrument as obs


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    rc: RunConfig
    params: object = None
    seed: int = 0

    def __post_init__(self):
        self.api = model_zoo.get_api(self.cfg, self.rc)
        if self.params is None:
            self.params = self.api.init(jax.random.PRNGKey(self.seed))
        self._step = jax.jit(self.api.decode_step)
        self._kv_bytes: dict = {}

    def kv_cache_bytes(self, batch: int) -> int:
        # eval_shape retraces the decode state on every call; cache per
        # batch size so per-generate gauge updates stay off the trace path
        cached = self._kv_bytes.get(batch)
        if cached is None:
            state = jax.eval_shape(lambda: self.api.init_decode_state(batch))
            cached = sum(np.prod(s.shape) * s.dtype.itemsize
                         for s in jax.tree.leaves(state.caches))
            self._kv_bytes[batch] = cached
        return cached

    def generate(self, prompts: List[List[int]], max_new: int = 16,
                 greedy: bool = True) -> List[List[int]]:
        """Batched generation; returns generated token lists per prompt."""
        B = len(prompts)
        lens = np.array([len(p) for p in prompts])
        total = int(lens.max() + max_new)
        assert total <= self.rc.seq_len, (total, self.rc.seq_len)
        prompt_buf = np.zeros((B, int(lens.max())), np.int32)
        for i, p in enumerate(prompts):
            prompt_buf[i, :len(p)] = p

        if obs.enabled():
            obs.gauge_set("serve/kv_bytes", int(self.kv_cache_bytes(B)),
                          arch=self.cfg.name,
                          kv_bits=self.rc.kv_cache_bits)
        t_start = time.perf_counter()
        # spans/counters wrap the jitted decode step from outside; nothing
        # records inside the traced function (see repro.obs)
        with obs.span("serve/generate", arch=self.cfg.name, batch=B,
                      max_new=max_new):
            state = self.api.init_decode_state(B)
            out_tokens = [[] for _ in range(B)]
            cur = prompt_buf[:, 0].copy()
            for t in range(total - 1):
                logits, state = self._step(self.params, state,
                                           jnp.asarray(cur, jnp.int32))
                nxt_model = np.asarray(jnp.argmax(logits, axis=-1))
                nxt = np.zeros((B,), np.int32)
                for i in range(B):
                    if t + 1 < lens[i]:
                        nxt[i] = prompt_buf[i, t + 1]   # still in prompt
                    else:
                        nxt[i] = nxt_model[i]
                        if len(out_tokens[i]) < max_new:
                            out_tokens[i].append(int(nxt_model[i]))
                cur = nxt
        if obs.enabled():
            n_gen = sum(len(t) for t in out_tokens)
            obs.counter_inc("serve/generated_tokens", n_gen,
                            arch=self.cfg.name)
            obs.counter_inc("serve/decode_steps", total - 1,
                            arch=self.cfg.name)
            obs.hist_observe("serve/generate_ms",
                             (time.perf_counter() - t_start) * 1e3,
                             arch=self.cfg.name)
        return out_tokens
