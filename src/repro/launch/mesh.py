"""Production mesh construction (function, never module-level state)."""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512).

    REPRO_MULTI_SHAPE=2,8,16 overrides the multi-pod shape (used to scope an
    XLA SPMD partitioner abort that is specific to certain subgroup sizes).
    """
    if multi_pod:
        shape = tuple(int(x) for x in os.environ.get(
            "REPRO_MULTI_SHAPE", "2,16,16").split(","))
        return jax.make_mesh(shape, ("pod", "data", "model"))
    return jax.make_mesh((16, 16), ("data", "model"))


def make_host_mesh():
    """Whatever this host offers, as a 1D data mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
