"""Production mesh construction (function, never module-level state)."""
from __future__ import annotations

import os

import jax


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across the API drift.

    Newer jax takes ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x takes a
    single ``((name, size), ...)`` shape tuple.  Rule resolution and spec
    tests only need ``.axis_names`` / ``.shape``, which both forms provide.
    """
    axis_sizes = tuple(axis_sizes)
    axis_names = tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512).

    REPRO_MULTI_SHAPE=2,8,16 overrides the multi-pod shape (used to scope an
    XLA SPMD partitioner abort that is specific to certain subgroup sizes).
    """
    if multi_pod:
        shape = tuple(int(x) for x in os.environ.get(
            "REPRO_MULTI_SHAPE", "2,16,16").split(","))
        return jax.make_mesh(shape, ("pod", "data", "model"))
    return jax.make_mesh((16, 16), ("data", "model"))


def make_host_mesh():
    """Whatever this host offers, as a 1D data mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
