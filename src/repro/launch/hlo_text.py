"""Shared HLO-text parsing: one parser for walk, audit, roofline, analysis.

Three consumers used to carry their own copies of the same regexes and
shape arithmetic (``hlo_walk`` for the execution-count walk, ``roofline``
for per-line collective bytes, ``launch.audit`` indirectly through both).
This module is the single source of truth they — and the static analyzer
``repro.analysis`` — all build on:

* dtype byte table and the ``dtype[dims]`` shape regex,
* ``shapes_info`` / ``first_shape_bytes`` shape arithmetic,
* the instruction grammar (``Instr`` + ``parse_computations``),
* ``find_entry`` (ENTRY-header aware, no proximity guessing),
* small lexical helpers (``operand_segment``, ``braced``,
  ``operand_names``).

Everything here is pure text processing — no jax import, safe to use from
tooling that must not initialize a backend.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Tuple

#: bytes per element for every dtype XLA prints in shape strings
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

#: ``dtype[d0,d1,...]`` occurrences inside a shape-or-tuple string
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: collective instruction mnemonics (base form, no -start/-done suffix)
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

#: ``%name = <result shape> op(...)`` instruction grammar
INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[\w\[\],{}\/\* ]+?))\s*([\w\-]+)\((.*)$")

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")


@dataclasses.dataclass
class Instr:
    """One parsed HLO instruction line."""
    name: str
    result_text: str
    op: str
    rhs: str
    root: bool = False


def shape_bytes(m: re.Match) -> int:
    """Bytes of one SHAPE_RE match (0 for layout tokens / unknown dtypes)."""
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def shapes_info(text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """(total bytes, [(dtype, dims), ...]) for a shape-or-tuple string."""
    total = 0
    shapes = []
    for m in SHAPE_RE.finditer(text):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


def first_shape_bytes(text: str) -> int:
    """Bytes of the first array shape in a shape-or-tuple string."""
    for m in SHAPE_RE.finditer(text):
        if m.group(1) in DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d]
            n = 1
            for d in dims:
                n *= d
            return n * DTYPE_BYTES[m.group(1)]
    return 0


def operand_segment(rhs: str) -> str:
    """The operand list of ``op(...)`` — rhs text up to the matching ')'."""
    depth = 1
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rhs[:i]
    return rhs


def braced(text: str, start: int) -> str:
    """Balanced ``{...}`` segment starting at ``text[start]``."""
    assert text[start] == "{", text[start:start + 20]
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    return text[start:]


def operand_names(rhs: str) -> Iterator[str]:
    """Referenced ``%name``s in an rhs, metadata trailer excluded."""
    for m in re.finditer(r"%([\w\.\-]+)", rhs.split(" metadata")[0]):
        yield m.group(1)


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    """Computation name -> parsed instruction list, module-wide."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        header = _HEADER_RE.match(stripped)
        if header and not line.startswith(" "):
            cur = header.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            # end of computation body (only top-level closers)
            if not line.startswith(" "):
                cur = None
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(name=m.group(1), result_text=m.group(2),
                                    op=m.group(3), rhs=m.group(4),
                                    root=stripped.startswith("ROOT")))
    return comps


def find_entry(hlo: str, comps: Dict[str, List[Instr]]) -> Optional[str]:
    """Name of the ENTRY computation.

    Parsed from the ``ENTRY %name (...)`` header itself — guessing by
    proximity ("some computation name occurs near the ENTRY keyword") picks
    a fusion body whenever one is referenced early in the entry body, which
    zeroes every execution count downstream.
    """
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next((n for n in comps if n.startswith("main")),
                next(iter(comps), None))


def entry_parameters(comps: Dict[str, List[Instr]],
                     entry: Optional[str]) -> Dict[str, int]:
    """ENTRY parameter name -> parameter index."""
    out: Dict[str, int] = {}
    for ins in comps.get(entry or "", []):
        if ins.op == "parameter":
            mnum = re.match(r"(\d+)", ins.rhs)
            out[ins.name] = int(mnum.group(1)) if mnum else len(out)
    return out
