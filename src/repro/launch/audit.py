"""HLO-vs-analytic audit: prove the byte/FLOP models against compiled XLA.

The repo carries three *analytic* bandwidth models that the paper's claims
rest on:

  * ``distributed/collectives.ExchangeStats`` — wire bytes of the
    compressed cross-pod gradient exchange (planes + scale markers),
  * ``kernels/ops.py`` ``*_io_bytes`` — per-kernel HBM traffic ("read
    every input once, write every output once"),
  * ``launch/roofline.py`` — collective bytes parsed per instruction.

This module is the enforcement that those models describe what XLA
actually compiles.  Each audit lowers a small canonical program, walks the
optimized HLO with ``launch/hlo_walk.py`` (execution-count, replica-group
and dtype aware), and compares the HLO-derived number against the analytic
one:

  * **wire**: the gradient-exchange program (quantize pod-locally,
    all-gather planes+scales across 'pod', pmean raw leaves) compiled on a
    2-pod mesh.  With group size 2 the ring-schedule wire bytes of the
    compiled collectives equal ``ExchangeStats.wire_bytes`` *exactly* —
    an all-gather moves (g-1) one-pod buffers and an all-reduce
    2(g-1)/g of the leaf, both == the analytic charge at g=2.
  * **parsers**: on the same module, ``roofline.collective_bytes`` (the
    independent line parser) must agree with ``analyze_hlo``'s
    per-collective totals (loop-free module -> exact).
  * **kernel IO**: each jitted ref kernel's ENTRY parameter/result bytes
    must equal the ``ops.*_io_bytes`` charge.
  * **flops**: a scan-of-matmul program's walked FLOPs must match the
    trip-count-aware analytic count (tolerance for XLA fusion slack).

``python -m repro.launch.audit`` prints the divergence report and exits
nonzero when any check diverges; ``--perturb-analytic X`` multiplies the
analytic side (CI self-test that the gate actually fires).  The bench
section ``benchmarks/bench_audit.py`` publishes the report as ``audit/*``
series so ``repro.obs.regress`` gates drift per PR.

Byte comparisons are exact (relative tolerance 1e-9 — float round-off
only); FLOPs get a 25% band (fusion/padding slack).  Conventions are
documented in ``src/repro/obs/README.md``.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # pragma: no cover - CLI needs a multi-dev host
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

#: exact tolerance for byte checks (float round-off only)
BYTES_RTOL = 1e-9
#: FLOP checks allow fusion/padding slack
FLOPS_RTOL = 0.25

#: default audit grid — mirrors benchmarks/bench_collectives.py smoke
SIZES = [1 << 16]
BITS = [4, 8]

N_PODS = 2


@dataclasses.dataclass
class AuditCheck:
    """One HLO-derived vs analytic comparison."""
    name: str
    hlo_value: float
    analytic_value: float
    rel_tol: float = BYTES_RTOL
    unit: str = "bytes"
    detail: str = ""

    @property
    def rel_error(self) -> float:
        ref = max(abs(self.analytic_value), 1.0)
        return abs(self.hlo_value - self.analytic_value) / ref

    @property
    def diverged(self) -> bool:
        return self.rel_error > self.rel_tol

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["rel_error"] = self.rel_error
        d["diverged"] = self.diverged
        return d


def summarize(checks: List[AuditCheck],
              programs: Optional[List[dict]] = None) -> dict:
    """Report dict (JSON-serializable) for a list of checks."""
    return {
        "checks": [c.to_dict() for c in checks],
        "programs": programs or [],
        "n_checks": len(checks),
        "divergences": sum(c.diverged for c in checks),
        "ok": not any(c.diverged for c in checks),
    }


def perturb_analytic(checks: List[AuditCheck], factor: float) -> List[AuditCheck]:
    """Scale the analytic side of every check (gate self-test)."""
    return [dataclasses.replace(c, analytic_value=c.analytic_value * factor)
            for c in checks]


def render_report(report: dict) -> str:
    from repro.launch.report import md_table
    rows = []
    for c in report["checks"]:
        rows.append((c["name"], c["unit"],
                     f"{c['hlo_value']:.6g}", f"{c['analytic_value']:.6g}",
                     f"{c['rel_error']:.2e}",
                     "DIVERGED" if c["diverged"] else "ok"))
    table = md_table(("check", "unit", "hlo", "analytic", "rel_err",
                      "status"), rows)
    tail = (f"\n{report['n_checks']} checks — "
            f"{report['divergences']} divergence(s)")
    return "# HLO-vs-analytic audit\n\n" + table + tail


def publish_report(report: dict) -> None:
    """Emit ``audit/*`` series (no-op when obs is disabled).

    ``audit/hlo_<unit>``/``audit/analytic_<unit>`` are deterministic
    functions of the pinned XLA version and the analytic models, so the
    regression gate compares them exactly; ``audit/divergences`` must stay
    at its baseline of 0.
    """
    from repro.obs import instrument as obs
    if not obs.enabled():
        return
    obs.counter_inc("audit/checks", report["n_checks"])
    obs.counter_inc("audit/divergences", report["divergences"])
    for c in report["checks"]:
        obs.gauge_set(f"audit/hlo_{c['unit']}", c["hlo_value"],
                      check=c["name"])
        obs.gauge_set(f"audit/analytic_{c['unit']}", c["analytic_value"],
                      check=c["name"])
        obs.gauge_set("audit/rel_error", c["rel_error"], check=c["name"])


# ---------------------------------------------------------------------------
# Canonical programs (lazy jax imports — the pure half above stays
# importable without initializing a backend)
# ---------------------------------------------------------------------------

def _grad_tree_abstract(n: int):
    """Abstract mirror of benchmarks/bench_collectives._grad_tree."""
    import jax
    import jax.numpy as jnp
    return {
        "w": jax.ShapeDtypeStruct((n // 128, 128), jnp.float32),
        "norm_scale": jax.ShapeDtypeStruct((7,), jnp.float32),
    }


def _exchange_hlo(tree_abs, bits: int) -> str:
    """Compile the canonical cross-pod exchange; return optimized HLO.

    Full-manual ``shard_map`` over a pod-only mesh (no auto axes, no while
    ops — the partial-auto + while combination aborts this XLA's SPMD
    partitioner): each pod quantizes its own full-size gradient, all-gathers
    planes+scales across 'pod', and dequant-averages; raw-fallback leaves
    cross via ``lax.pmean``.  Dequant is applied per gathered pod slice so
    the gather cannot be reassociated into an all-reduce.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives

    mesh = jax.make_mesh((N_PODS,), ("pod",))
    leaves, _ = jax.tree.flatten(tree_abs)
    comp = [collectives.compressible(l) for l in leaves]

    def body(*locals_):
        outs = []
        for x1, is_c in zip(locals_, comp):
            x = x1[0]
            if not is_c:
                outs.append(jax.lax.pmean(x, "pod"))
                continue
            planes, scale = collectives._quant_lastdim(x, bits)
            gp = jax.lax.all_gather(planes, "pod")
            gs = jax.lax.all_gather(scale, "pod")
            total = None
            for i in range(N_PODS):
                d = collectives._dequant_lastdim(gp[i], gs[i], bits, x.shape)
                total = d if total is None else total + d
            outs.append(total / N_PODS)
        return tuple(outs)

    sm = collectives.shard_map(
        body, mesh=mesh, axis_names=frozenset({"pod"}),
        in_specs=tuple(P("pod") for _ in leaves),
        out_specs=tuple(P() for _ in leaves))
    args = [jax.ShapeDtypeStruct((N_PODS,) + l.shape, l.dtype)
            for l in leaves]
    return jax.jit(sm).lower(*args).compile().as_text()


def wire_audit(n: int, bits: int) -> Tuple[List[AuditCheck], dict]:
    """Exchange wire bytes: walked HLO collectives vs ``ExchangeStats``."""
    from repro.distributed import collectives
    from repro.launch import hlo_walk, roofline

    tree_abs = _grad_tree_abstract(n)
    stats = collectives.exchange_stats(tree_abs, bits)
    hlo = _exchange_hlo(tree_abs, bits)
    walk = hlo_walk.analyze_hlo(hlo)

    hlo_wire = sum(d.wire_bytes for d in walk["collective_details"])
    checks = [AuditCheck(
        name=f"wire/n{n}/bits{bits}",
        hlo_value=hlo_wire, analytic_value=float(stats.wire_bytes),
        detail=f"{len(walk['collective_details'])} collectives; "
               f"{stats.compressed_leaves} compressed + "
               f"{stats.raw_leaves} raw leaves")]

    # independent parser agreement: roofline's per-line collective_bytes
    # vs the walker's per-collective max(result, operand) totals
    rl_total = float(sum(roofline.collective_bytes(hlo).values()))
    walk_total = float(sum(walk["collectives"].values()))
    checks.append(AuditCheck(
        name=f"parsers/n{n}/bits{bits}",
        hlo_value=walk_total, analytic_value=rl_total,
        detail="hlo_walk vs roofline collective parsers"))

    prog = {"name": f"exchange/n{n}/bits{bits}",
            "dma_bytes": walk["dma_bytes"],
            "flops": walk["flops"],
            "collectives": walk["collective_wire_bytes"],
            "n_collectives": len(walk["collective_details"])}
    return checks, prog


def kernel_io_audit() -> List[AuditCheck]:
    """ENTRY parameter/result bytes of jitted ref kernels vs ``ops``."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref
    from repro.launch import hlo_walk

    n, block = 256, 32
    rows, d = 64, 64
    jn = 4096
    t_steps = 4

    def lower(fn, *specs):
        return jax.jit(fn).lower(*specs).compile().as_text()

    s = jax.ShapeDtypeStruct
    cases = []
    for bits in (4, 8):
        cases.append((
            f"kernel/pack/bits{bits}",
            lower(lambda q, b=bits: ref.pack_ref(q, b),
                  s((n, block), jnp.int32)),
            ops.pack_io_bytes(n, block, bits)))
        cases.append((
            f"kernel/unpack/bits{bits}",
            lower(lambda p, b=bits: ref.unpack_ref(p, b, block),
                  s((n, block // 32 * bits), jnp.uint32)),
            ops.unpack_io_bytes(n, block, bits)))
        cases.append((
            f"kernel/kv_quant/bits{bits}",
            lower(lambda x, b=bits: ref.kv_quant_ref(x, b),
                  s((rows, d), jnp.float32)),
            ops.kv_quant_io_bytes(rows, d, bits)))
        cd = d if bits == 8 else d // 2
        cases.append((
            f"kernel/kv_dequant/bits{bits}",
            lower(lambda c, sc, b=bits: ref.kv_dequant_ref(c, sc, b),
                  s((rows, cd), jnp.int8), s((rows,), jnp.float32)),
            ops.kv_dequant_io_bytes(rows, d, bits)))
    cases.append((
        "kernel/jacobi1d",
        lower(lambda x: ref.jacobi_chunked_ref(x, t_steps),
              s((jn,), jnp.float32)),
        ops.jacobi_io_bytes(jn)))

    checks = []
    for name, hlo, (want_r, want_w) in cases:
        got_r, got_w = hlo_walk.entry_io_bytes(hlo)
        checks.append(AuditCheck(name=f"{name}/read",
                                 hlo_value=float(got_r),
                                 analytic_value=float(want_r)))
        checks.append(AuditCheck(name=f"{name}/write",
                                 hlo_value=float(got_w),
                                 analytic_value=float(want_w)))
    return checks


def flops_audit() -> AuditCheck:
    """Trip-count-aware walked FLOPs of a scan-of-matmul vs analytic."""
    import jax
    import jax.numpy as jnp

    from repro.launch import hlo_walk

    n, k = 128, 10

    def f(x, w):
        def step(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, None, length=k)
        return y

    s = jax.ShapeDtypeStruct((n, n), jnp.float32)
    hlo = jax.jit(f).lower(s, s).compile().as_text()
    walked = hlo_walk.analyze_hlo(hlo)["flops"]
    return AuditCheck(name=f"flops/scan_matmul/n{n}/k{k}",
                      hlo_value=float(walked),
                      analytic_value=float(k * 2 * n ** 3),
                      rel_tol=FLOPS_RTOL, unit="flops",
                      detail="while trip count x dot contracting dims")


def build_report(sizes: List[int], bits_grid: List[int],
                 perturb: float = 1.0) -> dict:
    import jax
    checks: List[AuditCheck] = []
    programs: List[dict] = []
    if len(jax.devices()) >= N_PODS:
        for n in sizes:
            for bits in bits_grid:
                cs, prog = wire_audit(n, bits)
                checks.extend(cs)
                programs.append(prog)
    else:  # pragma: no cover - defensive: wire audit needs a 2-pod mesh
        programs.append({"name": "exchange", "skipped":
                         f"only {len(jax.devices())} device(s)"})
    checks.extend(kernel_io_audit())
    checks.append(flops_audit())
    if perturb != 1.0:
        checks = perturb_analytic(checks, perturb)
    return summarize(checks, programs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Cross-validate compiled-HLO bytes/FLOPs against the "
                    "analytic collective/kernel/roofline models.")
    ap.add_argument("--sizes", type=int, nargs="+", default=SIZES)
    ap.add_argument("--bits", type=int, nargs="+", default=BITS)
    ap.add_argument("--json", help="also write the report as JSON")
    ap.add_argument("--perturb-analytic", type=float, default=1.0,
                    help="multiply analytic values (self-test: any value "
                         "!= 1.0 must make the audit exit nonzero)")
    args = ap.parse_args(argv)

    report = build_report(args.sizes, args.bits,
                          perturb=args.perturb_analytic)
    print(render_report(report))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.json}")
    if not report["ok"]:
        print("\nFAIL: HLO-derived traffic diverged from the analytic "
              "model — fix the model (or hlo_walk) before trusting the "
              "roofline/bandwidth numbers.")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
