"""Render the dry-run JSON grid into the EXPERIMENTS.md roofline tables.

``python -m repro.launch.report [--out benchmarks/out/dryrun]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch import cells


def fmt_s(x):
    """Human seconds: ``1.23s`` / ``4.5ms`` / ``-`` for missing."""
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def pct(x):
    return "-" if x is None else f"{100 * x:.1f}%"


def md_table(header, rows):
    """Markdown table from a header tuple + row tuples (all stringified)."""
    lines = ["| " + " | ".join(str(h) for h in header) + " |",
             "|" + "---|" * len(header)]
    lines.extend("| " + " | ".join(str(c) for c in row) + " |"
                 for row in rows)
    return "\n".join(lines)


# shared with repro.obs.report; old private names kept for callers
_fmt_s = fmt_s
_pct = pct


def load(outdir, tag=""):
    recs = {}
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        if len(parts) == 3 + (1 if tag else 0):
            if tag and parts[-1] != tag:
                continue
            if not tag and len(parts) != 3:
                continue
            with open(p) as f:
                recs[tuple(parts[:3])] = json.load(f)
    return recs


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch | shape | kind | compute | memory (lowered / kernelized) | "
        "collective | dominant | MF/HLO | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            lines.append(f"| {a} | {s} | - | FAILED: {r.get('error','?')} | "
                         "| | | | |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {a} | {s} | {r['kind']} | {_fmt_s(rl['compute_s'])} | "
            f"{_fmt_s(rl['memory_s'])} / {_fmt_s(rl.get('memory_s_kernelized'))} | "
            f"{_fmt_s(rl['collective_s'])} | {rl['dominant']} -> "
            f"{rl.get('dominant_kernelized', rl['dominant'])} | "
            f"{rl['model_flops_ratio']:.2f} | "
            f"{_pct(rl.get('mfu_bound_kernelized'))} |")
    for (a, s), why in sorted(cells.SKIPS.items()):
        lines.append(f"| {a} | {s} | skip | — | — | — | — | — | — |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | lower | compile | args/dev | temp/dev | "
        "collective bytes/dev (top ops) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(recs.items()):
        if not r.get("ok"):
            lines.append(f"| {a} | {s} | {m} | FAILED | {r.get('error','?')[:60]} | | | |")
            continue
        coll = r.get("collectives", {})
        top = ", ".join(f"{k}:{v / 1e9:.2f}GB"
                        for k, v in sorted(coll.items(), key=lambda kv: -kv[1])[:3])
        args_gb = r.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = r.get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {a} | {s} | {m} | {r.get('lower_s','-')}s | "
            f"{r.get('compile_s','-')}s | {args_gb:.2f}GB | {temp_gb:.2f}GB | "
            f"{top} |")
    return "\n".join(lines)


def main():
    from repro.launch.dryrun import OUT_DIR  # sets XLA_FLAGS; import lazily
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "both"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.out, args.tag)
    if args.table in ("roofline", "both"):
        print(roofline_table(recs, args.mesh))
    if args.table in ("dryrun", "both"):
        print()
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
