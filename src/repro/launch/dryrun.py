import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
platform devices stand in for 2 pods x 256 chips.  For every cell the step
function (train_step / prefill / decode_step) is jit'd with explicit
in/out shardings, ``.lower()``ed against ShapeDtypeStruct inputs (no
allocation) and ``.compile()``d; we record

  * cost_analysis()  — per-device FLOPs / bytes for §Roofline,
  * memory_analysis() — per-device argument/output/temp bytes (fits-proof),
  * the collective schedule parsed from the optimized HLO.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # orchestrates subprocesses
  python -m repro.launch.dryrun --all --mesh multi
Results land in benchmarks/out/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "out", "dryrun")


def run_cell(arch: str, shape: str, mesh_kind: str, overrides=None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import base
    from repro.distributed import sharding as shd
    from repro.launch import cells, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.models import model_zoo
    from repro.train import step as ts

    t_start = time.time()
    import dataclasses as _dc
    overrides = dict(overrides or {})
    cfg_over = {k[4:]: overrides.pop(k) for k in list(overrides)
                if k.startswith("cfg.")}
    cfg = base.load_arch(arch)
    if cfg_over:
        cfg = _dc.replace(cfg, **cfg_over)
    rc = cells.resolve_run_config(arch, shape, **overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "kind": rc.kind,
           "chips": chips, "ok": False}

    rules = shd.Rules(mesh=mesh, seq_shard=rc.seq_shard, fsdp=rc.fsdp,
                      shard_vocab=rc.shard_vocab)
    with shd.use_rules(rules):
        api = model_zoo.get_api(cfg, rc)
        ns = lambda spec: NamedSharding(mesh, spec)

        params_logical = api.param_specs()
        params_abs = api.abstract_params()
        params_sh = jax.tree.map(ns, shd.spec_tree(params_logical, params_abs))

        batch_abs = model_zoo.input_specs(cfg, rc)
        batch_logical = model_zoo.batch_logical_specs(cfg, rc)
        batch_sh = {k: ns(rules.spec(batch_abs[k].shape, batch_logical[k]))
                    for k in batch_abs}

        if rc.kind == "train":
            step_fn = ts.make_train_step(api, cfg, rc, mesh)
            state_abs = ts.abstract_state(api, rc, mesh)
            state_sh = jax.tree.map(
                ns, ts.resolve_state_specs(
                    ts.state_logical_specs(api, rc, mesh), state_abs))
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            args = (state_abs, batch_abs)
        elif rc.kind == "prefill":
            jitted = jax.jit(lambda p, b: api.prefill(p, b),
                             in_shardings=(params_sh, batch_sh))
            args = (params_abs, batch_abs)
        else:  # decode
            state_abs = jax.eval_shape(
                lambda: api.init_decode_state(rc.global_batch))
            state_logical = api.decode_state_specs()
            state_sh = jax.tree.map(ns, shd.spec_tree(state_logical, state_abs))
            tok_sh = batch_sh["tokens"]
            jitted = jax.jit(lambda p, s, t: api.decode_step(p, s, t),
                             in_shardings=(params_sh, state_sh, tok_sh),
                             out_shardings=(None, state_sh),
                             donate_argnums=(1,))
            args = (params_abs, state_abs, batch_abs["tokens"])

        t0 = time.time()
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        # --- analyses -----------------------------------------------------
        # raw XLA numbers (while bodies counted ONCE — kept for reference)
        from repro.launch import hlo_walk
        ca = hlo_walk.cost_analysis_dict(compiled)
        rec["xla_flops_raw"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))

        ma = compiled.memory_analysis()
        if ma is not None:
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                v = getattr(ma, field, None)
                if v is not None:
                    rec[field] = int(v)
        print("memory_analysis:", ma)

        # trip-count-aware walk of the partitioned module (per-device numbers)
        from repro.launch import hlo_walk
        hlo = compiled.as_text()
        if os.environ.get("REPRO_DUMP_HLO"):
            with open(os.environ["REPRO_DUMP_HLO"], "w") as f:
                f.write(hlo)
        walk = hlo_walk.analyze_hlo(hlo)
        rec["flops_per_device"] = float(walk["flops"])
        rec["bytes_per_device"] = float(walk["traffic_bytes"])
        rec["scoped_traffic"] = walk["scoped_traffic"]
        rec["collectives"] = walk["collectives"]
        rec["hlo_bytes"] = len(hlo)

        # kernelized deployment: scoped interiors (flash-attn / SSD chunk)
        # run as Pallas kernels on TPU — their HBM traffic collapses to I/O
        interior = float(sum(walk["scoped_traffic"].values()))
        kio = roofline.kernelized_io_bytes(cfg, rc, chips)
        rec["bytes_per_device_kernelized"] = max(
            rec["bytes_per_device"] - interior, 0.0) + kio

        rec["model_flops"] = roofline.model_flops_for(cfg, rc)
        rl = roofline.analyze(rec["flops_per_device"], rec["bytes_per_device"],
                              rec["collectives"], chips, rec["model_flops"])
        rlk = roofline.analyze(rec["flops_per_device"],
                               rec["bytes_per_device_kernelized"],
                               rec["collectives"], chips, rec["model_flops"])
        rec["roofline"] = {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "memory_s_kernelized": rlk.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "dominant_kernelized": rlk.dominant,
            "model_flops_ratio": rl.model_flops_ratio,
            "mfu_bound": rl.mfu_bound,
            "mfu_bound_kernelized": rlk.mfu_bound,
        }
        print("cost_analysis: flops=%.3e bytes=%.3e" %
              (rec["flops_per_device"], rec["bytes_per_device"]))
        print("collectives:", rec["collectives"])
        print("roofline:", json.dumps(rec["roofline"], indent=1))
        rec["ok"] = True
        rec["total_s"] = round(time.time() - t_start, 2)
    return rec


def cell_path(outdir, arch, shape, mesh_kind, tag=""):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tag", default="", help="experiment tag for §Perf runs")
    ap.add_argument("--override", action="append", default=[],
                    help="RunConfig overrides key=value (e.g. kv_cache_bits=8)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    if args.all:
        from repro.launch import cells
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        todo = [(a, s, m) for (a, s) in cells.runnable_cells() for m in meshes]
        failed = []
        for a, s, m in todo:
            path = cell_path(args.out, a, s, m, args.tag)
            if os.path.exists(path) and not args.force:
                try:
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"skip (done): {a} {s} {m}")
                            continue
                except (json.JSONDecodeError, OSError):
                    pass
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m, "--out", args.out]
            if args.tag:
                cmd += ["--tag", args.tag]
            for kv in args.override:
                cmd += ["--override", kv]
            print(f"=== {a} {s} {m} ===", flush=True)
            r = subprocess.run(cmd, timeout=args.timeout)
            if r.returncode != 0:
                failed.append((a, s, m))
        print("FAILED CELLS:", failed)
        sys.exit(1 if failed else 0)

    assert args.arch and args.shape
    path = cell_path(args.out, args.arch, args.shape, args.mesh, args.tag)
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, overrides)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(rec["traceback"], file=sys.stderr)
    if overrides:
        rec["overrides"] = overrides
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {path} ok={rec['ok']}")
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
