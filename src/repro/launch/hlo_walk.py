"""Execution-count-aware HLO analyzer.

XLA's ``cost_analysis()`` visits each instruction once, so anything inside a
``while`` body (our scan-over-layers, attention block scans, SSD chunk scans)
is under-counted by its trip count.  The optimized HLO text carries
``backend_config={"known_trip_count":{"n":...}}`` on every counted loop, so
we rebuild the call graph (ENTRY -> while bodies -> fusions), propagate
execution counts, and accumulate:

  * dot FLOPs        = 2 x prod(result dims) x prod(lhs contracting dims)
  * collective bytes = max(result, operand) bytes per collective op
  * traffic bytes    = operands + results of top-level compute instructions
                       (an HBM-traffic proxy: fusions read inputs and write
                       outputs; intermediates stay in registers/VMEM)

All shapes in the partitioned module are per-device, so totals are per-chip.

Text parsing (shape regex, instruction grammar, ENTRY discovery) lives in
the shared :mod:`repro.launch.hlo_text` helper — this module adds the
execution-count propagation and byte/FLOP accounting on top.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from . import hlo_text
from .hlo_text import (Instr, first_shape_bytes as _first_shape_bytes,
                       operand_segment as _operand_segment,
                       parse_computations as _parse_computations,
                       shapes_info as _shapes_info)

_DTYPE_BYTES = hlo_text.DTYPE_BYTES
_SHAPE_RE = hlo_text.SHAPE_RE
_COLLECTIVES = hlo_text.COLLECTIVE_OPS
_braced = hlo_text.braced

#: pod size for cross-pod (DCI) attribution on the 512-chip mesh
POD = 256


def _crosses_pod(rhs: str) -> Optional[bool]:
    """Does this collective's replica group span the pod boundary (512 mesh)?

    Handles iota groups ``replica_groups=[R,D]<=[dims...](T(perm))?`` and
    explicit ``{{a,b,...},...}`` lists; returns None if undeterminable or
    not a 512-device module.
    """
    import numpy as np
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  rhs)
    if m:
        r, d = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = int(np.prod(dims))
        if total != 2 * POD:
            return None
        ids = np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = np.transpose(ids, perm)
        groups = ids.reshape(r, d)
        pods = groups // POD
        return bool((pods.min(axis=1) != pods.max(axis=1)).any())
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
    if m:
        first = [int(x) for x in m.group(1).split(",")]
        if max(first) < 2 * POD:
            return len({i // POD for i in first}) > 1
    m = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", rhs)
    if m:  # collective-permute
        a, b = int(m.group(1)), int(m.group(2))
        return a // POD != b // POD
    return None


@dataclasses.dataclass
class CollectiveDetail:
    """One collective instruction, execution-count and replica-group aware.

    ``wire_bytes`` is the per-device ring-schedule wire volume over all
    executions: with group size g, an all-gather/reduce-scatter/all-to-all
    of a B-byte full buffer moves (g-1)/g * B per device, an all-reduce
    moves 2*(g-1)/g * B (reduce-scatter + all-gather phases), and a
    collective-permute moves B point-to-point.  ``group_size == 0`` means
    the group could not be determined (no replica_groups annotation and no
    num_partitions header) and the asymptotic g -> inf factor is used.
    """
    op: str
    name: str
    dtype: str
    group_size: int
    n_groups: int
    exec_count: float
    shape_bytes: int        # max(result, operand) per-device, one execution
    wire_bytes: float
    crosses_pod: Optional[bool] = None

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _ring_wire_bytes(op: str, group_size: int, shape_bytes: float) -> float:
    """Per-device ring-schedule wire bytes for one execution (see above)."""
    if op == "collective-permute":
        return float(shape_bytes)
    frac = (group_size - 1) / group_size if group_size > 0 else 1.0
    if op == "all-reduce":
        return 2.0 * frac * shape_bytes
    return frac * shape_bytes


def _group_info(rhs: str, default_size: int = 0) -> Tuple[int, int]:
    """(group size, n groups) from a replica_groups annotation."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", rhs)
    if m:
        return int(m.group(2)), int(m.group(1))
    m = re.search(r"replica_groups=\{\{", rhs)
    if m:
        seg = _braced(rhs, rhs.index("replica_groups=") + len("replica_groups="))
        groups = [g for g in seg.strip("{}").split("},{") if g.strip()]
        first = [x for x in groups[0].split(",") if x.strip()] if groups else []
        return len(first), len(groups)
    m = re.search(r"source_target_pairs=\{", rhs)
    if m:
        return 2, 0
    return default_size, 1


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across the API drift.

    Older jax returns a one-element list of per-partition dicts; newer jax
    returns the dict directly (and may return None for unsupported
    backends).  Always hands back a (possibly empty) dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


def entry_io_bytes(hlo: str) -> Tuple[int, int]:
    """(parameter bytes, root result bytes) of the ENTRY computation.

    For a jitted kernel this is exactly the "read every input once, write
    every output once" charge the analytic ``kernels/ops.py`` model makes —
    the audit compares the two.
    """
    comps = _parse_computations(hlo)
    entry = _find_entry(hlo, comps)
    params = roots = 0
    for ins in comps.get(entry, []):
        if ins.op == "parameter":
            params += _shapes_info(ins.result_text)[0]
        if ins.root:
            roots += _shapes_info(ins.result_text)[0]
    return params, roots


_find_entry = hlo_text.find_entry


def analyze_hlo(hlo: str) -> Dict[str, object]:
    comps = _parse_computations(hlo)

    # global name -> result bytes/shape text (instruction names unique per module)
    result_text_of: Dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            result_text_of[ins.name] = ins.result_text

    # --- call graph with multipliers -------------------------------------
    entry = _find_entry(hlo, comps)
    counts: Dict[str, float] = {n: 0.0 for n in comps}
    if entry:
        counts[entry] = 1.0

    edges: Dict[str, List[Tuple[str, float]]] = {n: [] for n in comps}
    #: computations whose instructions are NOT schedulable ops (fusion bodies,
    #: reduce/sort/map apply regions) — they contribute no top-level traffic
    inlined: set = set()
    #: computations owned by a named kernel scope (callee of a tagged while);
    #: optimizer-derived instructions lose their metadata, so ownership is
    #: propagated structurally down the call graph
    scope_seed: Dict[str, str] = {}
    _SCOPE_NAMES = ("flash_attn_interior", "ssd_interior",
                    "decode_attn_interior")
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                for sc in _SCOPE_NAMES:
                    if sc in ins.rhs:
                        for mm in re.finditer(
                                r"(?:body|condition)=%([\w\.\-]+)", ins.rhs):
                            scope_seed[mm.group(1)] = sc
                        break
                trip = 1.0
                mt = re.search(r'known_trip_count[^0-9]*"n"\s*:\s*"?(\d+)',
                               ins.rhs)
                if mt:
                    trip = float(mt.group(1))
                mb = re.search(r"body=%([\w\.\-]+)", ins.rhs)
                mc = re.search(r"condition=%([\w\.\-]+)", ins.rhs)
                if mb:
                    edges[cname].append((mb.group(1), trip))
                if mc:
                    edges[cname].append((mc.group(1), trip + 1))
            elif ins.op == "fusion":
                mf = re.search(r"calls=%([\w\.\-]+)", ins.rhs)
                if mf:
                    edges[cname].append((mf.group(1), 1.0))
                    inlined.add(mf.group(1))
            elif ins.op in ("call", "async-start"):
                mf = re.search(r"to_apply=%([\w\.\-]+)", ins.rhs)
                if mf:
                    edges[cname].append((mf.group(1), 1.0))
            elif ins.op == "conditional":
                for mb in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{?)[^%]*%([\w\.\-]+)", ins.rhs):
                    edges[cname].append((mb.group(1), 1.0))
            else:
                # reduce/sort/map/scatter/... apply regions are inlined
                mf = re.search(r"to_apply=%([\w\.\-]+)", ins.rhs)
                if mf:
                    edges[cname].append((mf.group(1), 1.0))
                    inlined.add(mf.group(1))
                mf2 = re.search(r"select=%([\w\.\-]+)", ins.rhs)
                if mf2:
                    inlined.add(mf2.group(1))

    # propagate scope ownership down the call graph
    comp_scope: Dict[str, str] = dict(scope_seed)
    for _ in range(64):
        changed = False
        for cname, tag in list(comp_scope.items()):
            for callee, _m in edges.get(cname, []):
                if callee not in comp_scope:
                    comp_scope[callee] = tag
                    changed = True
        if not changed:
            break

    # fixed-point propagation (call graph is a DAG; few iterations suffice)
    for _ in range(64):
        changed = False
        new = {n: 0.0 for n in comps}
        if entry:
            new[entry] = 1.0
        for cname in comps:
            for callee, mult in edges[cname]:
                if callee in new:
                    new[callee] += counts.get(cname, 0.0) * mult
        for n in comps:
            tgt = new[n]
            if abs(tgt - counts.get(n, 0.0)) > 1e-9:
                changed = True
        if not changed:
            break
        counts = new

    # --- fusion parameter access analysis ---------------------------------
    # A fusion that merely slices (scan xs) or updates-in-place (scan ys /
    # cache writes) a big buffer only moves the slice/update region, not the
    # whole operand.  For each fusion body, work out per-parameter charges:
    #   param used only as the sliced operand of dynamic-slice  -> slice size
    #   param used only as the target of dynamic-update-slice   -> update size
    #                                                  (result aliased too)
    #   otherwise                                                -> full size
    fusion_access: Dict[str, Dict[int, Tuple[str, int]]] = {}
    for cname, instrs in comps.items():
        if cname not in inlined:
            continue
        params: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                mnum = re.match(r"(\d+)", ins.rhs)
                if mnum:
                    params[ins.name] = int(mnum.group(1))
        local_shape = {ins.name: ins.result_text for ins in instrs}
        access: Dict[int, Tuple[str, int]] = {}
        consumers: Dict[str, List[Instr]] = {p: [] for p in params}
        for ins in instrs:
            if ins.op == "parameter":
                continue
            for om in re.finditer(r"%([\w\.\-]+)",
                                  ins.rhs.split(" metadata")[0]):
                if om.group(1) in consumers:
                    consumers[om.group(1)].append(ins)
        for pname, idx in params.items():
            cons = consumers[pname]
            if len(cons) == 1 and cons[0].op == "dynamic-slice" and \
                    cons[0].rhs.split(",")[0].strip().lstrip("%") == pname:
                access[idx] = ("slice", _shapes_info(cons[0].result_text)[0])
            elif len(cons) == 1 and cons[0].op == "dynamic-update-slice":
                ops_m = re.findall(r"%([\w\.\-]+)",
                                   cons[0].rhs.split(" metadata")[0])
                if ops_m and ops_m[0] == pname and len(ops_m) > 1:
                    upd = _shapes_info(local_shape.get(ops_m[1], ""))[0]
                    access[idx] = ("dus", upd)
        if access:
            fusion_access[cname] = access

    # --- accumulate -------------------------------------------------------
    #: module-wide partition count (fallback group size for collectives
    #: printed without replica_groups)
    mnp = re.search(r"num_partitions=(\d+)", hlo[:hlo.find("\n")]
                    if "\n" in hlo else hlo)
    num_partitions = int(mnp.group(1)) if mnp else 0
    flops = 0.0
    coll: Dict[str, float] = {}
    details: List[CollectiveDetail] = []
    dma_bytes = 0.0
    traffic = 0.0
    #: HBM traffic inside named scopes that deploy as fused Pallas kernels
    #: (VMEM-resident on TPU) — reported separately so the roofline can show
    #: the as-lowered (XLA:CPU) and kernelized (TPU deployment) memory terms
    scoped: Dict[str, float] = {}
    _SCOPES = ("flash_attn_interior", "ssd_interior",
               "decode_attn_interior")
    skip_ops = {"get-tuple-element", "tuple", "bitcast", "parameter",
                "constant", "copy-start", "copy-done", "after-all"}
    for cname, instrs in comps.items():
        c = counts.get(cname, 0.0)
        if c <= 0:
            continue
        schedulable = cname not in inlined
        for ins in instrs:
            rbytes, rshapes = _shapes_info(ins.result_text)
            if ins.op == "dot":
                # result dims x contracting dims.  The lhs operand may be
                # typed ("dot(f32[128,128]{1,0} %gte.4, ...)" in compiled
                # modules) or bare ("dot(%a, ...)"), so take the first
                # %-name anywhere in the operand list, not at position 0 —
                # re.match here silently dropped the contracting dims (the
                # scan-matmul undercount ISSUE 8 leads with).
                lhs_m = re.search(r"%([\w\.\-]+)", ins.rhs)
                contract = 1
                mlc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
                if lhs_m and mlc and lhs_m.group(1) in result_text_of:
                    _, lshapes = _shapes_info(result_text_of[lhs_m.group(1)])
                    if lshapes:
                        ldims = lshapes[0][1]
                        for di in mlc.group(1).split(","):
                            if di:
                                contract *= ldims[int(di)]
                n_out = 1
                for _, dims in rshapes[:1]:
                    for d in dims:
                        n_out *= d
                flops += c * 2.0 * n_out * contract
            if ins.op in _COLLECTIVES or any(
                    ins.op == f"{k}-start" for k in _COLLECTIVES):
                base = ins.op.replace("-start", "")
                # operands may be typed ("all-gather(u32[8,4]{1,0} %p)") or
                # bare ("all-gather(%p)") — read inline shapes first, fall
                # back to resolving the %-names
                oseg = _operand_segment(ins.rhs)
                operand_bytes, _ = _shapes_info(oseg)
                if not operand_bytes:
                    operand_bytes = sum(
                        _shapes_info(result_text_of.get(om.group(1), ""))[0]
                        for om in re.finditer(r"%([\w\.\-]+)", oseg))
                shape_bytes = max(rbytes, operand_bytes)
                gsize, ngroups = _group_info(ins.rhs, num_partitions)
                xpod = _crosses_pod(ins.rhs)
                details.append(CollectiveDetail(
                    op=base, name=ins.name,
                    dtype=rshapes[0][0] if rshapes else "?",
                    group_size=gsize, n_groups=ngroups, exec_count=c,
                    shape_bytes=int(shape_bytes),
                    wire_bytes=c * _ring_wire_bytes(base, gsize, shape_bytes),
                    crosses_pod=xpod))
                key = ("xpod:" + base) if xpod else base
                coll[key] = coll.get(key, 0.0) + c * shape_bytes
            if ins.op in ("copy", "copy-start"):
                # DMA proxy: explicit copies move their result once
                # (copy-start results are (dest, src, ctx) tuples — charge
                # the destination buffer only, not the aliased source)
                dma_bytes += c * (rbytes if ins.op == "copy" else
                                  _first_shape_bytes(ins.result_text))
            if schedulable and ins.op not in skip_ops \
                    and not ins.op.endswith("-done"):
                # traffic proxy: results + named operands' result bytes.
                # Slice-family ops only touch the sliced region, and
                # dynamic-update-slice/scatter write in place — count the
                # moved region, not the full aliased operand.
                if ins.op in ("dynamic-slice", "slice", "gather"):
                    t = c * 2 * rbytes
                elif ins.op in ("dynamic-update-slice", "scatter"):
                    ops_m = re.findall(r"%([\w\.\-]+)",
                                       ins.rhs.split(" metadata")[0])
                    ubytes = (_shapes_info(result_text_of.get(ops_m[1], ""))[0]
                              if len(ops_m) > 1 else rbytes)
                    t = c * 2 * min(ubytes, rbytes)
                elif ins.op == "fusion":
                    mf = re.search(r"calls=%([\w\.\-]+)", ins.rhs)
                    access = fusion_access.get(mf.group(1), {}) if mf else {}
                    ops_m = re.findall(r"%([\w\.\-]+)",
                                       ins.rhs.split(" metadata")[0])
                    obytes = 0.0
                    aliased = False
                    for oi, oname in enumerate(ops_m):
                        full = _shapes_info(
                            result_text_of.get(oname, ""))[0]
                        kind, sz = access.get(oi, ("full", full))
                        if kind == "slice":
                            obytes += sz
                        elif kind == "dus":
                            obytes += sz
                            aliased = True
                        else:
                            obytes += full
                    rb = min(rbytes, obytes) if aliased else rbytes
                    t = c * (rb + obytes)
                else:
                    obytes = 0
                    for om in re.finditer(r"%([\w\.\-]+)",
                                          ins.rhs.split(" metadata")[0]):
                        obytes += _shapes_info(
                            result_text_of.get(om.group(1), ""))[0]
                    t = c * (rbytes + obytes)
                traffic += t
                tag = comp_scope.get(cname)
                if tag is None:
                    for sc in _SCOPES:
                        if sc in ins.rhs:
                            tag = sc
                            break
                if tag is not None:
                    scoped[tag] = scoped.get(tag, 0.0) + t

    wire: Dict[str, float] = {}
    for d in details:
        key = ("xpod:" + d.op) if d.crosses_pod else d.op
        wire[key] = wire.get(key, 0.0) + d.wire_bytes

    return {
        "flops": flops,
        "collectives": {k: int(v) for k, v in coll.items()},
        "collective_details": details,
        "collective_wire_bytes": wire,
        "dma_bytes": dma_bytes,
        "traffic_bytes": traffic,
        "scoped_traffic": {k: int(v) for k, v in scoped.items()},
        "n_computations": len(comps),
        "entry": entry,
    }
