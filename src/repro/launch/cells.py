"""The assigned (architecture x shape) grid: cells, skips, per-cell RunConfig.

40 cells total; skips (documented in DESIGN.md §5):
  * long_500k on pure full-attention archs — no sub-quadratic mechanism in
    the published configs (and whisper's decoder domain caps at 448);
  * runnable long_500k: mamba2 (SSM state), hymba (SSM + SWA ring cache),
    mixtral (SWA-4096 ring cache).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs import base

LONG_OK = {"mamba2-130m", "hymba-1.5b", "mixtral-8x7b"}

SKIPS: Dict[Tuple[str, str], str] = {}
for _a in base.ARCH_IDS:
    if _a not in LONG_OK:
        reason = ("decoder position domain caps at 448 (out-of-family shape)"
                  if _a == "whisper-tiny" else
                  "pure full attention: 512k dense KV per step is "
                  "quadratic-regime with no sub-quadratic mechanism in the "
                  "published config")
        SKIPS[(_a, "long_500k")] = reason


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in base.ARCH_IDS for s in base.SHAPES]


def runnable_cells() -> List[Tuple[str, str]]:
    return [c for c in all_cells() if c not in SKIPS]


def resolve_run_config(arch: str, shape: str, **overrides) -> base.RunConfig:
    """Per-cell RunConfig: defaults + arch-specific adjustments."""
    cfg = base.load_arch(arch)
    kw: Dict = {}
    if arch == "whisper-tiny":
        # 6 heads / enc_seq 1500: TP/SP indivisible -> replicate those dims
        kw["seq_shard"] = False
    if base.SHAPES[shape][2] == "decode":
        kw["seq_shard"] = False        # no sequence dim at decode
    if arch == "mamba2-130m":
        # SSD chunk dual form: keep chunks at 256; seq shard off (the scan
        # carries state across the whole sequence; SP variant is a §Perf item)
        kw["seq_shard"] = False
    kw.update(overrides)
    return base.run_config_for(shape, cfg, **kw)
