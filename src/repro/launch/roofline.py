"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Terms (TPU v5e targets):
  compute    = FLOPs_per_device            / 197e12  FLOP/s
  memory     = bytes_accessed_per_device   / 819e9   B/s
  collective = collective_bytes_per_device / 50e9    B/s (per-link ICI)

``cost_analysis()`` on the partitioned module reports per-device FLOPs/bytes;
collective bytes are parsed from the optimized HLO text (per-device shapes):
for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction we count max(result bytes, operand bytes) —
one link traversal per byte; ring all-reduce costs ~2x which we annotate but
do not fold in (methodology note in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from .hlo_text import (COLLECTIVE_OPS as _COLLECTIVES, SHAPE_RE as _SHAPE_RE,
                       shape_bytes as _shape_bytes)

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective op kind."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        rhs = m.group(1)
        for op in _COLLECTIVES:
            # match the op as the instruction name: "shape op(" or "(shape, ...) op("
            if re.search(rf"\)?\s{op}(-start|-done)?\(", " " + rhs):
                if f" {op}-done(" in " " + rhs:
                    continue  # counted at -start
                paren = rhs.index("(")
                result_part = rhs[:paren]
                operand_part = rhs[paren:]
                rbytes = sum(_shape_bytes(s)
                             for s in _SHAPE_RE.finditer(result_part))
                obytes = sum(_shape_bytes(s)
                             for s in _SHAPE_RE.finditer(operand_part))
                out[op] = out.get(op, 0) + max(rbytes, obytes)
                break
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float = 0.0       # 6*N*D (or 6*N_active*D)
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPS (global): how much compiled compute is useful."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else None

    @property
    def mfu_bound(self) -> Optional[float]:
        """Achievable MFU if the dominant term were perfectly overlapped:
        useful model FLOPs / (chips * peak * bound_seconds)."""
        if not self.bound_seconds:
            return None
        return (self.model_flops
                / (self.chips * PEAK_FLOPS * self.bound_seconds))


def analyze(flops_per_device: float, bytes_per_device: float,
            coll: Dict[str, int], chips: int,
            model_flops: float = 0.0) -> Roofline:
    cb = float(sum(coll.values()))
    return Roofline(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=cb / ICI_BW,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=cb,
        model_flops=model_flops,
        chips=chips,
    )


def kernelized_io_bytes(cfg, rc, chips: int) -> float:
    """Per-device q/k/v/o (and SSD in/out) I/O of the fused TPU kernels.

    When the scoped interiors run as Pallas kernels, their HBM traffic is the
    kernel I/O: attention reads q,k,v and writes o once per layer per pass;
    SSD reads x,B,C,dt and writes y.  passes: train fwd + remat fwd + bwd
    reads ~= 4; prefill/decode 1.
    """
    passes = 4.0 if rc.kind == "train" else 1.0
    B, S = rc.global_batch, rc.seq_len
    if rc.kind == "decode":
        # fused dequant-attention kernel: reads the packed cache (codes +
        # scale markers) once per step per layer; SSM state reads are
        # unscoped (left in the general traffic count)
        if not cfg.n_heads:
            return 0.0
        s_cache = S if not cfg.sliding_window else min(S, cfg.sliding_window)
        bits = rc.kv_cache_bits
        per_pos = cfg.n_kv_heads * (cfg.hd * bits // 8
                                    + (4 if bits != 16 else 0))
        return cfg.n_layers * 2.0 * B * s_cache * per_pos / chips
    total = 0.0
    hd = cfg.hd if cfg.n_heads else 0
    attn_layers = 0
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        attn_layers = cfg.n_layers
    elif cfg.family == "encdec":
        attn_layers = cfg.n_layers * 2 + cfg.enc_layers  # self+cross+enc
    if attn_layers and cfg.n_heads:
        qo = 2 * B * S * cfg.n_heads * hd
        kv = 2 * B * S * cfg.n_kv_heads * hd
        total += attn_layers * (qo + kv) * 2.0  # bf16
    if cfg.family in ("ssm", "hybrid"):
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        io = B * S * (2 * di + 2 * N + 2 * H) * 4.0
        total += cfg.n_layers * io
    return passes * total / chips


def model_flops_for(cfg, rc) -> float:
    """6*N*D per step (dense) / 6*N_active*D (MoE); decode: D = batch tokens."""
    n = cfg.active_param_count()
    if rc.kind == "train":
        tokens = rc.global_batch * rc.seq_len
        return 6.0 * n * tokens
    if rc.kind == "prefill":
        tokens = rc.global_batch * rc.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = rc.global_batch              # one token per sequence
    return 2.0 * n * tokens
