"""Bandwidth regression gate: ``python -m repro.obs.regress <run> --baseline <b>``.

The paper's claim is a *measured* one (up to 7x fewer I/O cycles), and the
companion literature (Ferry et al. burst-friendly layouts; Zohouri &
Matsuoka's memory-controller wall) shows how silently such wins erode.
This module is the enforcement half of ``repro.obs``: it diffs the
``BENCH_obs.json`` sidecar of a fresh run against a committed baseline
(``benchmarks/baseline/``) and exits nonzero when a load-bearing series
regressed, so CI fails the PR that spent the cycles.

Tolerance policy (``GATES``):

* **logical** cycle/byte/beat counters (``transfer/cycles``,
  ``kernels/hbm_bytes``, ``collectives/wire_bytes``, ...) are deterministic
  functions of seeded data and analytic models — they are compared
  **exactly** (float epsilon only).  Any drift in the bad direction fails;
  drift in the good direction is reported as ``improved`` with a reminder
  to refresh the baseline.
* **wall-clock** series (``ckpt/save_ms``, ``train/step_ms``, ...) get a
  **percentage band** (``--wall-tol``, default allow 3x over baseline)
  because absolute times vary machine to machine; the band only catches
  order-of-magnitude pathology, the logical counters are the real gate.
* everything else is tracked in the table but never fails the run.

A series present in only one side is a warning, not a failure: smoke grids
legitimately grow and shrink, and a stale baseline must say "refresh me"
rather than block unrelated PRs.

Baseline refresh (see ``src/repro/obs/README.md``):

    python -m benchmarks.run --smoke --out benchmarks/out
    cp benchmarks/out/BENCH_obs.json benchmarks/baseline/BENCH_obs.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

from .metrics import parse_series_key
from .sink import read_summary

#: relative epsilon forgiving float round-off on "exact" comparisons
EXACT_EPS = 1e-6

#: default allowed fractional slowdown for wall-clock series (3.0 = 4x)
DEFAULT_WALL_TOL = 3.0

EXACT, WALL = "exact", "wall"

#: (metric-name prefix, better direction, tolerance kind) — first match wins.
GATES: List[Tuple[str, str, str]] = [
    ("transfer/cycles", "lower", EXACT),
    ("transfer/bits", "lower", EXACT),
    ("transfer/transactions", "lower", EXACT),
    ("burst/beats", "lower", EXACT),
    ("compression/ratio_padded", "higher", EXACT),
    ("compression/ratio", "higher", EXACT),
    ("codec/bits", "lower", EXACT),
    ("codec/words", "lower", EXACT),
    ("exec/compressed_bits", "lower", EXACT),
    ("exec/uncompressed_bits", "lower", EXACT),
    ("exec/full_tiles", "lower", EXACT),
    ("exec/host_tiles", "lower", EXACT),
    ("exec/mars_read", "lower", EXACT),
    ("exec/mars_written", "lower", EXACT),
    ("codec/bench_ms", "lower", WALL),
    ("codec/words_per_s", "higher", WALL),
    ("exec/tiles_per_s", "higher", WALL),
    ("kernels/hbm_bytes", "lower", EXACT),
    ("kernels/beats", "lower", EXACT),
    ("collectives/wire_bytes", "lower", EXACT),
    ("audit/divergences", "lower", EXACT),
    ("audit/hlo_bytes", "lower", EXACT),
    ("audit/analytic_bytes", "lower", EXACT),
    ("ckpt/bytes_written", "lower", EXACT),
    ("ckpt/bytes_read", "lower", EXACT),
    ("ckpt/save_ms", "lower", WALL),
    ("ckpt/restore_ms", "lower", WALL),
    ("train/step_ms", "lower", WALL),
    ("serve/generate_ms", "lower", WALL),
    ("data/batch_ms", "lower", WALL),
    ("analysis/findings", "lower", EXACT),
    ("analysis/new_findings", "lower", EXACT),
    ("analysis/pass_findings", "lower", EXACT),
]


def gate_for(metric_name: str) -> Optional[Tuple[str, str]]:
    """(direction, kind) for a metric name, or None if ungated."""
    for prefix, direction, kind in GATES:
        if metric_name == prefix or metric_name.startswith(prefix + "{"):
            return direction, kind
    return None


def flatten_series(doc: dict) -> Dict[str, dict]:
    """Sidecar -> flat ``{series_key: {kind, value[, count]}}``.

    The one number the gate compares per series: counters and gauges use
    their value, histograms their mean (``count`` is carried along so grid
    changes are visible).  This is the same view ``repro.obs.report
    --format=json`` prints — the gate and humans read identical numbers.
    """
    m = doc.get("metrics", {}) or {}
    out: Dict[str, dict] = {}
    for k, v in (m.get("counters", {}) or {}).items():
        out[k] = {"kind": "counter", "value": v}
    for k, v in (m.get("gauges", {}) or {}).items():
        out[k] = {"kind": "gauge", "value": v}
    for k, h in (m.get("histograms", {}) or {}).items():
        out[k] = {"kind": "histogram", "value": (h or {}).get("mean"),
                  "count": (h or {}).get("count")}
    return out


@dataclasses.dataclass
class Delta:
    """One compared series (or one side-only series)."""
    key: str
    status: str                    # ok | REGRESSION | improved | new |
    #                              # missing | untracked
    baseline: Optional[float] = None
    current: Optional[float] = None
    note: str = ""

    @property
    def failed(self) -> bool:
        return self.status == "REGRESSION"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _rel(base: Optional[float], cur: Optional[float]) -> Optional[float]:
    """Signed relative change cur vs base; None when undefined."""
    if base is None or cur is None:
        return None
    if base == 0:
        return None if cur == 0 else float("inf") * (1 if cur > 0 else -1)
    return (cur - base) / abs(base)


def compare(baseline: Dict[str, dict], current: Dict[str, dict],
            wall_tol: float = DEFAULT_WALL_TOL) -> List[Delta]:
    """Diff two flattened series maps under the ``GATES`` policy."""
    deltas: List[Delta] = []
    for key in sorted(set(baseline) | set(current)):
        name, _ = parse_series_key(key)
        gate = gate_for(name)
        b = baseline.get(key)
        c = current.get(key)
        if b is None:
            deltas.append(Delta(key, "new", None,
                                c.get("value"),
                                "no baseline series — refresh baseline"
                                if gate else ""))
            continue
        if c is None:
            deltas.append(Delta(key, "missing", b.get("value"), None,
                                "series vanished from run — refresh baseline"
                                if gate else ""))
            continue
        bv, cv = b.get("value"), c.get("value")
        d = Delta(key, "untracked", bv, cv)
        note = []
        if b.get("count") is not None and b.get("count") != c.get("count"):
            note.append(f"count {b['count']}->{c['count']}")
        if gate is None:
            d.note = "; ".join(note)
            deltas.append(d)
            continue
        direction, kind = gate
        rel = _rel(bv, cv)
        if bv is None or cv is None:
            d.status = "missing" if cv is None else "ok"
            d.note = "empty value"
        elif rel is None:
            d.status = "ok"
        else:
            worse = rel if direction == "lower" else -rel
            tol = wall_tol if kind == WALL else EXACT_EPS
            if worse > tol:
                d.status = "REGRESSION"
                note.append(f"{'+' if rel >= 0 else ''}{rel:.1%} vs "
                            f"{'exact' if kind == EXACT else 'wall'} "
                            f"tolerance {tol:.2g}")
            elif kind == EXACT and -worse > EXACT_EPS:
                d.status = "improved"
                note.append("refresh baseline to lock in the win")
            else:
                d.status = "ok"
        d.note = "; ".join(note)
        deltas.append(d)
    return deltas


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float) and not float(v).is_integer():
        return f"{v:.4g}"
    return str(int(v))


def render_table(deltas: List[Delta], verbose: bool = False) -> str:
    """Markdown delta table; quiet mode hides untracked/unchanged rows."""
    from repro.launch.report import md_table
    rows = []
    for d in deltas:
        if not verbose and d.status in ("untracked", "ok") and not d.note:
            continue
        rel = _rel(d.baseline, d.current)
        rows.append((d.key, _fmt(d.baseline), _fmt(d.current),
                     "n/a" if rel is None else f"{rel:+.2%}",
                     d.status, d.note))
    if not rows:
        return "(all tracked series unchanged)"
    return md_table(("series", "baseline", "current", "delta", "status",
                     "note"), rows)


def run_gate(run_path: str, baseline_path: str,
             wall_tol: float = DEFAULT_WALL_TOL) -> Tuple[List[Delta], dict]:
    """Load both sidecars, compare, and summarize. Returns (deltas, stats)."""
    base_doc = read_summary(baseline_path)
    cur_doc = read_summary(run_path)
    deltas = compare(flatten_series(base_doc), flatten_series(cur_doc),
                     wall_tol=wall_tol)
    stats = {
        "run": run_path,
        "baseline": baseline_path,
        "baseline_sha": (base_doc.get("meta") or {}).get("git_sha"),
        "run_sha": (cur_doc.get("meta") or {}).get("git_sha"),
        "compared": sum(d.status in ("ok", "REGRESSION", "improved")
                        for d in deltas),
        "regressions": sum(d.failed for d in deltas),
        "improved": sum(d.status == "improved" for d in deltas),
        "new": sum(d.status == "new" for d in deltas),
        "missing": sum(d.status == "missing" for d in deltas),
    }
    return deltas, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a run's BENCH_obs.json against a baseline and "
                    "fail on bandwidth/latency regressions.")
    ap.add_argument("run", help="run output dir (or sidecar file)")
    ap.add_argument("--baseline", required=True,
                    help="baseline sidecar (or dir), e.g. "
                         "benchmarks/baseline/BENCH_obs.json")
    ap.add_argument("--wall-tol", type=float, default=DEFAULT_WALL_TOL,
                    help="allowed fractional slowdown for wall-clock series "
                         "(default %(default)s, i.e. fail beyond "
                         "(1+tol)x baseline)")
    ap.add_argument("--format", choices=("md", "json"), default="md")
    ap.add_argument("--verbose", action="store_true",
                    help="also print unchanged/untracked rows")
    args = ap.parse_args(argv)

    try:
        deltas, stats = run_gate(args.run, args.baseline,
                                 wall_tol=args.wall_tol)
    except FileNotFoundError as e:
        ap.error(f"missing sidecar: {e.filename!r} — run "
                 "`python -m benchmarks.run --smoke --out <dir>` first")

    code = 1 if stats["regressions"] else 0
    if args.format == "json":
        print(json.dumps({"stats": stats, "exit_code": code,
                          "deltas": [d.to_dict() for d in deltas]},
                         indent=1, sort_keys=True))
        return code

    print(f"# obs regression gate\n\nbaseline: {args.baseline} "
          f"(sha {stats['baseline_sha'] or 'n/a'})\n"
          f"run:      {args.run} (sha {stats['run_sha'] or 'n/a'})\n")
    print(render_table(deltas, verbose=args.verbose))
    print(f"\n{stats['compared']} gated series compared — "
          f"{stats['regressions']} regression(s), "
          f"{stats['improved']} improved, {stats['new']} new, "
          f"{stats['missing']} missing")
    if stats["regressions"]:
        print("\nFAIL: bandwidth/latency regression vs baseline. If the "
              "change is intentional, refresh benchmarks/baseline/ (see "
              "src/repro/obs/README.md).")
    elif stats["improved"]:
        print("\nOK (improvements detected — refresh benchmarks/baseline/ "
              "to lock them in).")
    return code


if __name__ == "__main__":
    sys.exit(main())
