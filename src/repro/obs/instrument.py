"""Enable-gated instrumentation facade — the only obs API hot paths touch.

Design rule: *zero cost when disabled*.  Every helper starts with a single
module-flag test and returns immediately when obs is off; the disabled
``span()`` returns a shared null context (no allocation, no clock read).
Instrumentation must sit *around* ``jax.jit``-traced calls, never inside
them — a traced function runs as compiled XLA where Python side effects
do not execute (and would otherwise bake constants into the trace), so
callers record around ``jit_step(...)`` / ``self._step(...)`` boundaries.

Enable globally with ``REPRO_OBS=1`` in the environment, or per-scope::

    from repro import obs
    with obs.enabled_scope() as (registry, tracer):
        ...  # instrumented code publishes into this private pair

or imperatively with :func:`enable` / :func:`disable`.
"""
from __future__ import annotations

import functools
import os
import time
from contextlib import contextmanager
from typing import Callable, Iterator, Optional, Tuple

from . import metrics as _metrics
from . import trace as _trace

_enabled: bool = os.environ.get("REPRO_OBS", "").lower() in ("1", "true", "on")
_registry: _metrics.Registry = _metrics.REGISTRY
_tracer: _trace.Tracer = _trace.TRACER


def enabled() -> bool:
    return _enabled


def registry() -> _metrics.Registry:
    """The registry instrumentation currently publishes into."""
    return _registry


def tracer() -> _trace.Tracer:
    return _tracer


def enable(registry: Optional[_metrics.Registry] = None,
           tracer: Optional[_trace.Tracer] = None) -> None:
    """Turn instrumentation on, optionally onto private sinks."""
    global _enabled, _registry, _tracer
    if registry is not None:
        _registry = registry
    if tracer is not None:
        _tracer = tracer
    _enabled = True


def disable() -> None:
    """Turn instrumentation off and restore the default global sinks."""
    global _enabled, _registry, _tracer
    _enabled = False
    _registry = _metrics.REGISTRY
    _tracer = _trace.TRACER


@contextmanager
def disabled_scope() -> Iterator[None]:
    """Suppress recording inside the block; restore prior state on exit.

    For meta-tooling (e.g. ``repro.analysis``) that *executes* instrumented
    code paths on synthetic inputs — their series must not leak into the
    surrounding run's registry.
    """
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


@contextmanager
def enabled_scope(registry: Optional[_metrics.Registry] = None,
                  tracer: Optional[_trace.Tracer] = None
                  ) -> Iterator[Tuple[_metrics.Registry, _trace.Tracer]]:
    """Enable onto fresh (or given) sinks; restore prior state on exit."""
    global _enabled, _registry, _tracer
    prev = (_enabled, _registry, _tracer)
    reg = registry if registry is not None else _metrics.Registry()
    trc = tracer if tracer is not None else _trace.Tracer()
    enable(reg, trc)
    try:
        yield reg, trc
    finally:
        _enabled, _registry, _tracer = prev


# ---------------------------------------------------------------------------
# Recording helpers (no-ops when disabled)
# ---------------------------------------------------------------------------

def counter_inc(name: str, amount: float = 1, **labels) -> None:
    if not _enabled:
        return
    _registry.counter(name, **labels).inc(amount)


def gauge_set(name: str, value: float, **labels) -> None:
    if not _enabled:
        return
    _registry.gauge(name, **labels).set(value)


def hist_observe(name: str, value: float, **labels) -> None:
    if not _enabled:
        return
    _registry.histogram(name, **labels).observe(value)


class _NullSpan:
    """Inert stand-in yielded by the disabled ``span()``."""
    __slots__ = ()
    cycles = 0

    def add_cycles(self, n: int) -> None:
        pass

    def set(self, **kwargs) -> None:
        pass


class _NullCtx:
    __slots__ = ()
    _span = _NullSpan()

    def __enter__(self) -> _NullSpan:
        return self._span

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CTX = _NullCtx()


def span(name: str, **args):
    """Context manager: live tracer span when enabled, shared no-op if not."""
    if not _enabled:
        return _NULL_CTX
    return _tracer.span(name, **args)


def instrumented(name: Optional[str] = None, **labels
                 ) -> Callable[[Callable], Callable]:
    """Decorator: wrap calls in a span + ``<name>_ms`` latency histogram.

    The wrapper costs one flag test per call when disabled.  Apply to
    *host-side* functions only — never to a function that will itself be
    ``jax.jit``-traced (see module docstring).
    """
    def deco(fn: Callable) -> Callable:
        metric = name if name is not None else fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _enabled:
                return fn(*a, **kw)
            t0 = time.perf_counter()
            with _tracer.span(metric, **labels):
                out = fn(*a, **kw)
            _registry.histogram(f"{metric}_ms", **labels).observe(
                (time.perf_counter() - t0) * 1e3)
            return out

        return wrapper

    return deco
