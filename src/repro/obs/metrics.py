"""Lightweight metrics registry: counters, gauges, histograms with labels.

The paper's figure of merit is *measured* (§5: on-FPGA I/O-cycle counters),
so the reproduction keeps the same discipline in software: every hot-path
quantity — transfer cycles per access pattern, compressed vs padded bits,
executor tile counts, train step latency, serve KV bytes — is published
into a registry that benchmarks and tests can snapshot and assert against.

Naming conventions (see ``src/repro/obs/README.md``):

* metric names are ``<subsystem>/<quantity>`` (``transfer/cycles``,
  ``compression/ratio``, ``train/step_ms``);
* labels qualify a series (``pattern=mars_comp``, ``dtype=fixed18``); every
  distinct label set is an independent series;
* counters are monotonically accumulated ints/floats, gauges hold the last
  value, histograms keep count/sum/min/max plus power-of-two bucket counts.

The registry is pure Python with no third-party deps, safe to import from
``repro.core`` (no jax), and cheap enough that the *enabled* path costs a
dict lookup + add.  The *disabled* path never reaches this module — see
``repro.obs.instrument``.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Dict[str, object]) -> LabelSet:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def series_key(name: str, labels: Dict[str, object] | LabelSet | None) -> str:
    """Canonical ``name{k=v,...}`` series identifier (sorted label order)."""
    if not labels:
        return name
    if isinstance(labels, dict):
        labels = _labelset(labels)
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


def parse_series_key(key: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of :func:`series_key`."""
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    rest = rest.rstrip("}")
    labels = dict(item.split("=", 1) for item in rest.split(",") if item)
    return name, labels


class Counter:
    """Monotonic accumulator."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelSet):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} decremented by {amount}")
        self.value += amount


class Gauge:
    """Last-value holder."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelSet):
        self.name = name
        self.labels = labels
        self.value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Count/sum/min/max + power-of-two bucket counts.

    Buckets are implicit: observation ``v`` lands in bucket
    ``ceil(log2(v))`` for ``v > 0`` (bucket upper bound ``2**b``), with a
    dedicated ``<=0`` bucket.  This is exact enough for cycle counts and
    millisecond latencies while keeping the series O(64) in size.
    """
    __slots__ = ("name", "labels", "count", "sum", "min", "max", "buckets")

    def __init__(self, name: str, labels: LabelSet):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.buckets: Dict[int, int] = {}

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if self.min is None or value < self.min:
            self.min = value
        if self.max is None or value > self.max:
            self.max = value
        b = -1 if value <= 0 else max(0, math.ceil(math.log2(value)))
        self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None


@dataclasses.dataclass
class Snapshot:
    """Frozen, JSON-serializable view of a registry."""
    counters: Dict[str, float]
    gauges: Dict[str, Optional[float]]
    histograms: Dict[str, dict]

    def to_dict(self) -> dict:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: dict(v) for k, v in self.histograms.items()}}


class Registry:
    """Holds all metric series; thread-safe; snapshot/reset semantics."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- series accessors ---------------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = series_key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(name, _labelset(labels))
            return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = series_key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge(name, _labelset(labels))
            return g

    def histogram(self, name: str, **labels) -> Histogram:
        key = series_key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(name, _labelset(labels))
            return h

    # -- queries ------------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        c = self._counters.get(series_key(name, labels))
        return 0 if c is None else c.value

    def series(self, name: str) -> List[str]:
        """All series keys (any kind) for a metric name."""
        out = []
        for store in (self._counters, self._gauges, self._histograms):
            out.extend(k for k in store if parse_series_key(k)[0] == name)
        return sorted(out)

    def snapshot(self) -> Snapshot:
        with self._lock:
            return Snapshot(
                counters={k: c.value for k, c in self._counters.items()},
                gauges={k: g.value for k, g in self._gauges.items()},
                histograms={
                    k: {"count": h.count, "sum": h.sum, "min": h.min,
                        "max": h.max, "mean": h.mean,
                        "buckets": {str(b): n
                                    for b, n in sorted(h.buckets.items())}}
                    for k, h in self._histograms.items()},
            )

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def __len__(self) -> int:
        return (len(self._counters) + len(self._gauges)
                + len(self._histograms))


#: Process-wide default registry; ``repro.obs.instrument`` publishes here
#: unless :func:`repro.obs.instrument.enable` installed a private one.
REGISTRY = Registry()
