"""Exporters: JSONL event stream, summary dict, and BENCH_obs.json sidecar.

Three consumers, three shapes:

* **tests / benchmarks** assert against :func:`summary` — a plain dict
  (``{"meta": ..., "metrics": <Snapshot.to_dict()>, "spans": [...]}``);
* **perf-trajectory tooling** tails the JSONL stream written by
  :func:`write_jsonl` — one self-describing JSON object per line
  (``{"kind": "counter"|"gauge"|"histogram"|"span"|"meta", ...}``);
* **humans** run ``python -m repro.obs.report <outdir>`` over the
  ``BENCH_obs.json`` sidecar dropped by :func:`write_sidecar`.

All writers are pure stdlib.  They raise normally on I/O errors — callers
that want best-effort persistence wrap them; only :func:`run_metadata` is
deliberately best-effort (a missing git binary must not kill a benchmark).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List, Optional

from . import instrument
from .metrics import Registry, parse_series_key
from .trace import Tracer

SIDECAR_NAME = "BENCH_obs.json"


def _jsonable(obj):
    """json.dump default= hook: numpy scalars/arrays, tuples-as-keys, etc."""
    if hasattr(obj, "item"):        # numpy scalar
        return obj.item()
    if hasattr(obj, "tolist"):      # numpy array
        return obj.tolist()
    return str(obj)


def run_metadata(**extra) -> dict:
    """Reproducibility stamp: git SHA, interpreter, argv, plus ``extra``.

    Every value is best-effort — a missing git binary or a non-repo cwd
    yields ``git_sha=None`` rather than an exception, so benchmarks can
    stamp their outputs unconditionally.
    """
    sha = None
    dirty = None
    try:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10).stdout.strip() or None
        if sha:
            dirty = bool(subprocess.run(
                ["git", "status", "--porcelain"], cwd=root,
                capture_output=True, text=True, timeout=10).stdout.strip())
    except (OSError, subprocess.SubprocessError):
        pass
    meta = {
        "git_sha": sha,
        "git_dirty": dirty,
        "python": sys.version.split()[0],
        "argv": list(sys.argv),
    }
    meta.update(extra)
    return meta


def _span_dicts(tracer: Tracer) -> List[dict]:
    return [{"name": r.name, "ts_us": r.ts_us, "dur_us": r.dur_us,
             "depth": r.depth, "cycles": r.cycles, "args": dict(r.args)}
            for r in tracer.records]


def summary(registry: Optional[Registry] = None,
            tracer: Optional[Tracer] = None,
            meta: Optional[dict] = None) -> dict:
    """Single JSON-serializable dict for the whole run."""
    registry = registry if registry is not None else instrument.registry()
    tracer = tracer if tracer is not None else instrument.tracer()
    return {
        "meta": meta or {},
        "metrics": registry.snapshot().to_dict(),
        "spans": _span_dicts(tracer),
    }


def write_jsonl(path: str, registry: Optional[Registry] = None,
                tracer: Optional[Tracer] = None,
                meta: Optional[dict] = None) -> str:
    """One JSON object per line; first line is the run metadata."""
    registry = registry if registry is not None else instrument.registry()
    tracer = tracer if tracer is not None else instrument.tracer()
    snap = registry.snapshot()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "meta", **(meta or {})},
                            default=_jsonable) + "\n")
        for key, v in snap.counters.items():
            name, labels = parse_series_key(key)
            f.write(json.dumps({"kind": "counter", "name": name,
                                "labels": labels, "value": v},
                               default=_jsonable) + "\n")
        for key, v in snap.gauges.items():
            name, labels = parse_series_key(key)
            f.write(json.dumps({"kind": "gauge", "name": name,
                                "labels": labels, "value": v},
                               default=_jsonable) + "\n")
        for key, h in snap.histograms.items():
            name, labels = parse_series_key(key)
            f.write(json.dumps({"kind": "histogram", "name": name,
                                "labels": labels, **h},
                               default=_jsonable) + "\n")
        for s in _span_dicts(tracer):
            f.write(json.dumps({"kind": "span", **s},
                                default=_jsonable) + "\n")
    return path


def write_sidecar(outdir: str, registry: Optional[Registry] = None,
                  tracer: Optional[Tracer] = None,
                  meta: Optional[dict] = None,
                  name: str = SIDECAR_NAME) -> str:
    """Write ``<outdir>/BENCH_obs.json`` (+ Chrome trace when spans exist)."""
    os.makedirs(outdir, exist_ok=True)
    tracer = tracer if tracer is not None else instrument.tracer()
    path = os.path.join(outdir, name)
    with open(path, "w") as f:
        json.dump(summary(registry, tracer, meta), f, indent=1,
                  sort_keys=True, default=_jsonable)
        f.write("\n")
    if tracer.records:
        with open(os.path.join(outdir, "trace.json"), "w") as f:
            json.dump(tracer.chrome_trace(), f, default=_jsonable)
    return path


def read_summary(path: str) -> dict:
    """Load a summary written by :func:`write_sidecar` (file or outdir)."""
    if os.path.isdir(path):
        path = os.path.join(path, SIDECAR_NAME)
    with open(path) as f:
        return json.load(f)
