"""``repro.obs`` — metrics, trace spans, and I/O-cycle accounting.

The observability layer for the reproduction: a labeled metrics registry
(``metrics``), nested wall-clock + logical-cycle trace spans (``trace``),
JSONL / summary / sidecar exporters (``sink``), an enable-gated facade that
hot paths call (``instrument``), and a table-rendering CLI
(``python -m repro.obs.report``).

Typical use::

    from repro import obs

    with obs.enabled_scope() as (registry, tracer):
        with obs.span("tile_io", tile=(3, 4)) as sp:
            obs.counter_inc("transfer/cycles", 123, pattern="mars_comp")
            sp.add_cycles(123)
        doc = obs.summary(registry, tracer)

Disabled (the default unless ``REPRO_OBS=1``), every helper is a single
flag test — see ``instrument`` for the zero-overhead contract and the rule
about never recording inside ``jax.jit``-traced code.
"""
# NOTE: ``regress`` is deliberately not imported here — it is a ``-m``
# entry point (importing it from the package __init__ would make runpy
# warn about double execution); use ``from repro.obs import regress``.
from . import instrument, metrics, sink, trace
from .instrument import (counter_inc, disable, enable, enabled,
                         enabled_scope, gauge_set, hist_observe,
                         instrumented, registry, span, tracer)
from .metrics import Counter, Gauge, Histogram, Registry, Snapshot, series_key
from .sink import read_summary, run_metadata, summary, write_jsonl, write_sidecar
from .trace import Span, SpanRecord, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Snapshot", "Span",
    "SpanRecord", "Tracer", "counter_inc", "disable", "enable", "enabled",
    "enabled_scope", "gauge_set", "hist_observe", "instrument",
    "instrumented", "metrics", "read_summary", "registry", "run_metadata",
    "series_key", "sink", "span", "summary", "trace", "tracer",
    "write_jsonl", "write_sidecar",
]
