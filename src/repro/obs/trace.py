"""Nested trace spans with wall-clock *and* logical-cycle attribution.

``span("tile_io", tile=t)`` opens a nested region; closing it records one
Chrome-trace "complete" event (``ph="X"``) with microsecond ``ts``/``dur``.
Spans also carry a logical-cycle tally: the transfer model of
``repro.core.transfer`` measures I/O in bus cycles, not seconds, so a span
can be charged cycles via :meth:`Span.add_cycles` and the trace shows both
time bases side by side — exactly how the paper pairs wall-clock runs with
on-FPGA cycle counters (§5).

Export with :meth:`Tracer.chrome_trace`; the result loads directly into
``chrome://tracing`` / Perfetto (``{"traceEvents": [...]}``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class SpanRecord:
    """One closed span (Chrome trace "X" event)."""
    name: str
    ts_us: float           # start, microseconds since tracer epoch
    dur_us: float
    depth: int             # nesting depth at open time (0 = root)
    args: Dict[str, object]
    cycles: int = 0        # logical I/O cycles charged to this span

    def to_chrome(self, pid: int = 0, tid: int = 0) -> dict:
        args = dict(self.args)
        if self.cycles:
            args["cycles"] = self.cycles
        return {"name": self.name, "ph": "X", "ts": self.ts_us,
                "dur": self.dur_us, "pid": pid, "tid": tid, "args": args}


class Span:
    """Live (open) span handle yielded by :meth:`Tracer.span`."""
    __slots__ = ("name", "args", "cycles", "_t0", "_depth")

    def __init__(self, name: str, args: Dict[str, object], depth: int,
                 t0: float):
        self.name = name
        self.args = args
        self.cycles = 0
        self._t0 = t0
        self._depth = depth

    def add_cycles(self, n: int) -> None:
        self.cycles += int(n)

    def set(self, **kwargs) -> None:
        self.args.update(kwargs)


class Tracer:
    """Collects closed spans; thread-local nesting stacks."""

    def __init__(self) -> None:
        self._epoch = time.perf_counter()
        self._local = threading.local()
        self._lock = threading.Lock()
        self.records: List[SpanRecord] = []

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @property
    def depth(self) -> int:
        return len(self._stack())

    def current(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    @contextmanager
    def span(self, name: str, **args) -> Iterator[Span]:
        st = self._stack()
        sp = Span(name, args, depth=len(st), t0=time.perf_counter())
        st.append(sp)
        try:
            yield sp
        finally:
            st.pop()
            t1 = time.perf_counter()
            rec = SpanRecord(
                name=sp.name,
                ts_us=(sp._t0 - self._epoch) * 1e6,
                dur_us=(t1 - sp._t0) * 1e6,
                depth=sp._depth,
                args=sp.args,
                cycles=sp.cycles,
            )
            with self._lock:
                self.records.append(rec)
            # roll logical cycles up into the parent so root spans carry
            # the subtree total, like a sampling profiler's inclusive time
            parent = self.current()
            if parent is not None:
                parent.cycles += sp.cycles

    def chrome_trace(self, pid: int = 0) -> dict:
        with self._lock:
            events = [r.to_chrome(pid=pid, tid=r.depth)
                      for r in sorted(self.records, key=lambda r: r.ts_us)]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def reset(self) -> None:
        with self._lock:
            self.records.clear()
        self._local = threading.local()
        self._epoch = time.perf_counter()


#: Process-wide default tracer (mirrors ``metrics.REGISTRY``).
TRACER = Tracer()
