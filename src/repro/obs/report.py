"""Render a run's obs sidecar as tables: ``python -m repro.obs.report <outdir>``.

Reads the ``BENCH_obs.json`` written by ``benchmarks/run.py`` (or any
:func:`repro.obs.sink.write_sidecar` caller) and prints:

* per-pattern transfer-cycle counters (the paper's Fig. 10 axis:
  minimal / bbox / mars / mars_pack / mars_comp), grouped by benchmark,
  tile, and dtype;
* compression-ratio and bit-size histograms (Fig. 11 axis);
* every remaining counter / gauge / histogram series;
* a span rollup (count, wall-clock total, logical-cycle total per name).

Formatting reuses the markdown-table and duration helpers from
``repro.launch.report`` so EXPERIMENTS.md-style docs stay consistent.
"""
from __future__ import annotations

import argparse
from collections import defaultdict
from typing import Dict, Tuple

from repro.launch.report import fmt_s, md_table

from repro.core.transfer import MODES as TRANSFER_PATTERNS

from .metrics import parse_series_key
from .sink import read_summary


def _fmt_val(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    return str(int(v))


def transfer_cycles_table(counters: Dict[str, float]) -> str:
    """Pivot ``transfer/cycles{...}`` counters: one column per pattern."""
    cells: Dict[Tuple[str, str, str], Dict[str, float]] = defaultdict(dict)
    for key, v in counters.items():
        name, labels = parse_series_key(key)
        if name != "transfer/cycles":
            continue
        row = (labels.get("bench", "?"), labels.get("tile", "?"),
               labels.get("dtype", "?"))
        cells[row][labels.get("pattern", "?")] = v
    if not cells:
        return "(no transfer/cycles counters in this run)"
    rows = []
    for (bench, tile, dtype), by_pat in sorted(cells.items()):
        rows.append((bench, tile, dtype,
                     *[_fmt_val(by_pat.get(p)) for p in TRANSFER_PATTERNS]))
    return md_table(("bench", "tile", "dtype", *TRANSFER_PATTERNS), rows)


def histogram_table(histograms: Dict[str, dict], prefix: str = "") -> str:
    rows = []
    for key, h in sorted(histograms.items()):
        if not key.startswith(prefix):
            continue
        rows.append((key, h["count"], _fmt_val(h["min"]),
                     _fmt_val(h["mean"]), _fmt_val(h["max"]),
                     _fmt_val(h["sum"])))
    if not rows:
        return f"(no {prefix or 'histogram'}* series in this run)"
    return md_table(("series", "count", "min", "mean", "max", "sum"), rows)


def scalar_table(series: Dict[str, float], kind: str) -> str:
    rows = [(k, _fmt_val(v)) for k, v in sorted(series.items())]
    if not rows:
        return f"(no {kind}s in this run)"
    return md_table(("series", "value"), rows)


def span_table(spans) -> str:
    agg: Dict[str, list] = defaultdict(lambda: [0, 0.0, 0])
    for s in spans:
        a = agg[s["name"]]
        a[0] += 1
        a[1] += s["dur_us"]
        a[2] += s.get("cycles", 0)
    if not agg:
        return "(no spans in this run)"
    rows = [(name, n, fmt_s(us / 1e6), _fmt_val(cyc))
            for name, (n, us, cyc) in sorted(agg.items())]
    return md_table(("span", "count", "wall total", "cycles total"), rows)


def render(doc: dict) -> str:
    meta = doc.get("meta", {})
    m = doc.get("metrics", {})
    counters = m.get("counters", {})
    histograms = m.get("histograms", {})
    out = []
    stamp = ", ".join(f"{k}={v}" for k, v in sorted(meta.items())
                      if k in ("git_sha", "config", "seed", "smoke")
                      and v is not None)
    out.append(f"# obs report ({stamp})" if stamp else "# obs report")
    out.append("\n## Transfer cycles by access pattern\n")
    out.append(transfer_cycles_table(counters))
    out.append("\n## Compression histograms\n")
    out.append(histogram_table(histograms, prefix="compression/"))
    out.append("\n## Counters\n")
    out.append(scalar_table(counters, "counter"))
    out.append("\n## Gauges\n")
    out.append(scalar_table(m.get("gauges", {}), "gauge"))
    out.append("\n## Other histograms\n")
    out.append(histogram_table(
        {k: v for k, v in histograms.items()
         if not k.startswith("compression/")}))
    out.append("\n## Spans\n")
    out.append(span_table(doc.get("spans", [])))
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Render BENCH_obs.json metrics as markdown tables.")
    ap.add_argument("path", help="run output dir (or sidecar file) to report")
    args = ap.parse_args(argv)
    try:
        doc = read_summary(args.path)
    except FileNotFoundError as e:
        ap.error(f"no obs sidecar at {e.filename!r} — run "
                 "`python -m benchmarks.run --smoke --out <dir>` first")
    print(render(doc))


if __name__ == "__main__":
    main()
