"""Render a run's obs sidecar as tables: ``python -m repro.obs.report <outdir>``.

Reads the ``BENCH_obs.json`` written by ``benchmarks/run.py`` (or any
:func:`repro.obs.sink.write_sidecar` caller) and prints:

* per-pattern transfer-cycle counters (the paper's Fig. 10 axis:
  minimal / bbox / mars / mars_pack / mars_comp), grouped by benchmark,
  tile, and dtype;
* compression-ratio and bit-size histograms (Fig. 11 axis);
* every remaining counter / gauge / histogram series;
* a span rollup (count, wall-clock total, logical-cycle total per name).

Sections a run did not exercise render as ``n/a`` placeholders rather than
raising — a smoke run without the beyond-paper benches must still report.
``--format=json`` emits the :func:`repro.obs.regress.flatten_series` view
instead, so the regression gate and humans read the same numbers.

Formatting reuses the markdown-table and duration helpers from
``repro.launch.report`` so EXPERIMENTS.md-style docs stay consistent.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from typing import Dict, Tuple

from repro.launch.report import fmt_s, md_table

from repro.core.transfer import MODES as TRANSFER_PATTERNS

from .metrics import parse_series_key
from .regress import flatten_series
from .sink import read_summary


def _fmt_val(v) -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    try:
        return str(int(v))
    except (TypeError, ValueError):
        return str(v)


def transfer_cycles_table(counters: Dict[str, float]) -> str:
    """Pivot ``transfer/cycles{...}`` counters: one column per pattern."""
    cells: Dict[Tuple[str, str, str], Dict[str, float]] = defaultdict(dict)
    for key, v in counters.items():
        name, labels = parse_series_key(key)
        if name != "transfer/cycles":
            continue
        row = (labels.get("bench", "?"), labels.get("tile", "?"),
               labels.get("dtype", "?"))
        cells[row][labels.get("pattern", "?")] = v
    if not cells:
        return "(n/a — no transfer/cycles counters in this run)"
    rows = []
    for (bench, tile, dtype), by_pat in sorted(cells.items()):
        rows.append((bench, tile, dtype,
                     *[_fmt_val(by_pat.get(p)) for p in TRANSFER_PATTERNS]))
    return md_table(("bench", "tile", "dtype", *TRANSFER_PATTERNS), rows)


def histogram_table(histograms: Dict[str, dict], prefix: str = "") -> str:
    rows = []
    for key, h in sorted(histograms.items()):
        if not key.startswith(prefix):
            continue
        h = h or {}
        rows.append((key, _fmt_val(h.get("count")), _fmt_val(h.get("min")),
                     _fmt_val(h.get("mean")), _fmt_val(h.get("max")),
                     _fmt_val(h.get("sum"))))
    if not rows:
        return f"(n/a — no {prefix or 'histogram'}* series in this run)"
    return md_table(("series", "count", "min", "mean", "max", "sum"), rows)


def scalar_table(series: Dict[str, float], kind: str) -> str:
    rows = [(k, _fmt_val(v)) for k, v in sorted(series.items())]
    if not rows:
        return f"(n/a — no {kind}s in this run)"
    return md_table(("series", "value"), rows)


def span_table(spans) -> str:
    agg: Dict[str, list] = defaultdict(lambda: [0, 0.0, 0])
    for s in spans or []:
        a = agg[s.get("name", "?")]
        a[0] += 1
        a[1] += s.get("dur_us", 0.0) or 0.0
        a[2] += s.get("cycles", 0) or 0
    if not agg:
        return "(n/a — no spans in this run)"
    rows = [(name, n, fmt_s(us / 1e6), _fmt_val(cyc))
            for name, (n, us, cyc) in sorted(agg.items())]
    return md_table(("span", "count", "wall total", "cycles total"), rows)


def render(doc: dict) -> str:
    meta = doc.get("meta", {}) or {}
    m = doc.get("metrics", {}) or {}
    counters = m.get("counters", {}) or {}
    histograms = m.get("histograms", {}) or {}
    out = []
    stamp = ", ".join(f"{k}={v}" for k, v in sorted(meta.items())
                      if k in ("git_sha", "config", "seed", "smoke")
                      and v is not None)
    out.append(f"# obs report ({stamp})" if stamp else "# obs report")
    out.append("\n## Transfer cycles by access pattern\n")
    out.append(transfer_cycles_table(counters))
    out.append("\n## Compression histograms\n")
    out.append(histogram_table(histograms, prefix="compression/"))
    out.append("\n## Counters\n")
    out.append(scalar_table(counters, "counter"))
    out.append("\n## Gauges\n")
    out.append(scalar_table(m.get("gauges", {}) or {}, "gauge"))
    out.append("\n## Other histograms\n")
    out.append(histogram_table(
        {k: v for k, v in histograms.items()
         if not k.startswith("compression/")}))
    out.append("\n## Spans\n")
    out.append(span_table(doc.get("spans", [])))
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Render BENCH_obs.json metrics as markdown tables.")
    ap.add_argument("path", help="run output dir (or sidecar file) to report")
    ap.add_argument("--format", choices=("md", "json"), default="md",
                    help="json prints the flat series view the regression "
                         "gate compares (repro.obs.regress.flatten_series)")
    args = ap.parse_args(argv)
    try:
        doc = read_summary(args.path)
    except FileNotFoundError as e:
        ap.error(f"no obs sidecar at {e.filename!r} — run "
                 "`python -m benchmarks.run --smoke --out <dir>` first")
    if args.format == "json":
        print(json.dumps({"meta": doc.get("meta", {}) or {},
                          "series": flatten_series(doc)},
                         indent=1, sort_keys=True))
    else:
        print(render(doc))


if __name__ == "__main__":
    main()
