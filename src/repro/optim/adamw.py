"""Functional AdamW with global-norm clipping and cosine schedule.

Optimizer state dtype is configurable (``RunConfig.opt_dtype``): the largest
assigned archs (grok-1, qwen-110b, internvl-76b) use bf16 moments to fit the
v5e HBM budget (see DESIGN.md §6 / EXPERIMENTS.md memory table).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: object     # pytree like params
    nu: object
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    dtype: str = "float32"


def init(params, cfg: AdamConfig) -> AdamState:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
        count=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamState, params, cfg: AdamConfig
           ) -> Tuple[object, AdamState]:
    count = state.count + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) * (1 - lr * decay) - lr * step
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    # leaves are plain tuples; NamedTuple params nodes are not (type check)
    is_triple = lambda x: type(x) is tuple
    p_new = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    return p_new, AdamState(mu=mu, nu=nu, count=count)
