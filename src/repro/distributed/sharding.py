"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Models annotate tensors with *logical* axis names; this module resolves them
to mesh axes for the active mesh and run config:

  batch     -> ('pod', 'data')   data parallelism (pod axis included if present)
  seq       -> 'model'           sequence/context parallelism for activations
  heads     -> 'model'           attention-head tensor parallelism
  ff        -> 'model'           MLP hidden tensor parallelism
  vocab     -> 'model'           embedding/unembedding vocab sharding
  cache_seq -> 'model'           decode KV-cache length sharding (flash-decode)
  fsdp      -> 'data'            ZeRO-3 style parameter/optimizer sharding
  experts   -> None              baseline: experts TP-sharded via 'ff' inside
                                  (an EP mesh variant is a §Perf experiment)

A rule only applies when the dimension size divides the mesh axis size
(whisper's 6 heads, hymba's 32001 vocab etc. fall back to replication —
uneven shardings would silently pad and skew the roofline accounting).

No global state is touched by importing this module; the launcher installs a
context via ``use_rules`` / ``set_rules``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Optional[Mesh] = None
    seq_shard: bool = True
    fsdp: bool = True
    shard_vocab: bool = True
    #: axes handled manually (e.g. 'pod' inside a shard_map body) — excluded
    #: from constraint resolution
    exclude: frozenset = frozenset()

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]

    def resolve(self, logical: Optional[str], dim: int):
        """Logical name + dim size -> mesh axis (or None)."""
        if self.mesh is None or logical is None:
            return None
        names = tuple(a for a in self.mesh.axis_names if a not in self.exclude)
        if logical == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            total = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
            return axes if axes and dim % total == 0 else None
        if logical == "fsdp":
            if not self.fsdp:
                return None
            return "data" if "data" in names and dim % self.axis_size("data") == 0 else None
        if logical == "seq":
            if not self.seq_shard:
                return None
            return "model" if dim % self.axis_size("model") == 0 else None
        if logical == "vocab" and not self.shard_vocab:
            return None
        if logical in ("heads", "ff", "vocab", "cache_seq", "tp"):
            return "model" if dim % self.axis_size("model") == 0 else None
        if logical == "experts":
            return None
        raise KeyError(f"unknown logical axis {logical!r}")

    def spec(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical), (shape, logical)
        return P(*(self.resolve(l, d) for l, d in zip(logical, shape)))


_local = threading.local()


def set_rules(rules: Optional[Rules]) -> None:
    _local.rules = rules


def get_rules() -> Optional[Rules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Rules):
    prev = get_rules()
    set_rules(rules)
    try:
        yield rules
    finally:
        set_rules(prev)


def act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation to its logical sharding (no-op without mesh)."""
    r = get_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def _is_logical_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def spec_tree(logicals, shapes):
    """Resolve a pytree of logical tuples to PartitionSpecs (for in_shardings).

    ``logicals`` leaves are tuples of logical axis names (or None); ``shapes``
    is a matching pytree of arrays / ShapeDtypeStructs.
    """
    r = get_rules()
    if r is None:
        return jax.tree.map(lambda _: P(), logicals, is_leaf=_is_logical_leaf)
    return jax.tree.map(
        lambda log, shp: r.spec(shp.shape if hasattr(shp, "shape") else shp, log),
        logicals, shapes, is_leaf=_is_logical_leaf)


def named_sharding(spec: P) -> Optional[NamedSharding]:
    r = get_rules()
    if r is None or r.mesh is None:
        return None
    return NamedSharding(r.mesh, spec)


def tp_out_proj(h: jax.Array, w: jax.Array) -> Optional[jax.Array]:
    """Hand-scheduled tensor-parallel out-projection (§Perf iteration 1).

    ``h``: (B, S, F) activation with F (heads*hd or ff) sharded on 'model';
    ``w``: (F, d).  The contraction over the sharded F dim needs a cross-
    'model' reduction; left to GSPMD (on this backend) it materializes a
    full (B, S, d) f32 all-reduce *plus* an all-gather per layer.  Here the
    schedule is pinned manually: local partial matmul, then one bf16
    ``psum_scatter`` onto the seq dim (matching the seq-sharded residual
    stream) — 1/(2*tp) the bytes in one collective instead of two.

    Returns None when inapplicable (no mesh / tp=1 / indivisible dims) —
    caller falls back to the plain matmul.
    """
    r = get_rules()
    if r is None or r.mesh is None or "model" in r.exclude:
        return None
    tp = r.axis_size("model")
    if h.ndim != 3 or tp <= 1:
        return None
    B, S, F = h.shape
    if F % tp or w.shape[0] != F:
        return None
    scatter = (r.seq_shard and S % tp == 0 and S >= tp)
    mesh = r.mesh

    def body(hl, wl):
        # f32 accumulate/scatter: XLA:CPU's AllReducePromotion pass aborts
        # on bf16 reduce-scatter (TPU deployment would use bf16 wire, halving
        # these bytes again — noted in EXPERIMENTS.md §Perf)
        partial = jnp.dot(hl, wl, preferred_element_type=jnp.float32)
        if scatter:
            out = jax.lax.psum_scatter(partial, "model",
                                       scatter_dimension=1, tiled=True)
        else:
            out = jax.lax.psum(partial, "model")
        return out.astype(hl.dtype)

    from repro.distributed.collectives import shard_map
    out_spec = P(None, "model", None) if scatter else P(None, None, None)
    return shard_map(
        body, mesh=mesh, axis_names=frozenset({"model"}),
        in_specs=(P(None, None, "model"), P("model", None)),
        out_specs=out_spec, check_vma=False,
    )(h, w)
