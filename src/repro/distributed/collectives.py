"""Compressed cross-pod gradient exchange (the paper's technique, DESIGN §3.1).

Data-parallel gradients crossing the pod boundary (slow inter-pod links) are
the framework's dominant "inter-tile dataflow".  Each pod's gradient shard is
an atomic, irredundant block; before the cross-pod exchange it is quantized
to ``bits`` two's-complement codes per value with a per-block scale (the
markers analogue) and bitplane-packed (kernels/bitplane, TPU form of §2.4
packing), cutting cross-pod bytes by ~32/bits vs f32 (16/bits vs bf16).

Sharding-preservation invariant: the codec blocks along the LAST tensor axis
in groups of 32 and never reshapes across leading axes — flattening a
(model/data)-sharded gradient would force SPMD to rematerialize it
replicated, multiplying within-pod traffic (measured; see EXPERIMENTS.md
§Perf Cell D).  Leaves whose last axis is not 32-divisible (tiny: norms,
per-head scalars) are exchanged raw with ``lax.pmean``.

Error feedback (residual carried per pod in the optimizer state) makes the
lossy quantization unbiased over time — the divergence from the paper's
lossless codec and its rationale are documented in DESIGN.md §2.

The train step realizes the exchange in pure auto-GSPMD (a vmap over a
pod-sharded leading axis — this XLA's SPMD partitioner aborts on while ops
inside manual subgroups, see train/step.py); the equivalent manual-'pod'
``shard_map`` spelling is compiled and byte-audited against
``ExchangeStats`` by ``repro.launch.audit``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blockcodec as bc
from repro.obs import instrument as obs

F32 = jnp.float32
BLOCK = 32                 # values per scale block (= one bitplane group)
MIN_COMPRESS_SIZE = 4096   # smaller leaves go raw (scale overhead dominates)


def shard_map(f, *, mesh, axis_names, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across the API drift.

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``; 0.4.x
    only has ``jax.experimental.shard_map.shard_map`` whose ``auto`` set is
    the complement of ``axis_names`` and whose replication check is spelled
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=frozenset(axis_names),
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def _quant_lastdim(x: jax.Array, bits: int):
    """(..., last) f32 -> (planes uint32 (..., nb, bits), scale (..., nb))."""
    *lead, last = x.shape
    xb = x.reshape(*lead, last // BLOCK, BLOCK)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -qmax, qmax)
    planes = bc.bitplane_pack(q.astype(jnp.int32), bits)
    return planes, scale


def _dequant_lastdim(planes: jax.Array, scale: jax.Array, bits: int,
                     shape) -> jax.Array:
    q = bc.bitplane_unpack(planes, bits)
    x = q.astype(F32) * scale[..., None]
    return x.reshape(shape)


def compressible(g: jax.Array) -> bool:
    return g.size >= MIN_COMPRESS_SIZE and g.shape[-1] % BLOCK == 0


def quantize_tree(grads, resids, bits: int, axis_name: str = "pod"):
    """Pod-local half of the exchange (runs inside the manual-'pod' region).

    Compressible leaves -> (planes, scale, new_resid); small leaves are
    pod-pmean'd in place (their operands are replicated over data/model, the
    only in-manual collective shape the partitioner handles robustly).
    Returns (planes_tree, scales_tree, raw_means_tree, new_resids_tree) with
    None at non-applicable positions.
    """
    def one(g, r):
        if not compressible(g):
            mean = jax.lax.pmean(g.astype(F32), axis_name).astype(g.dtype)
            return (None, None, mean, jnp.zeros_like(r))
        x = g.astype(F32) + r
        planes, scale = _quant_lastdim(x, bits)
        new_resid = x - _dequant_lastdim(planes, scale, bits, x.shape)
        return (planes, scale, None, new_resid)

    out = jax.tree.map(one, grads, resids)
    is_q = lambda t: type(t) is tuple
    pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=is_q)
    return pick(0), pick(1), pick(2), pick(3)


def dequant_mean_tree(grads_like, planes, scales, raw_means, bits: int,
                      n_pods: int):
    """Auto-GSPMD half: planes/scales arrive with a leading pod dim (sharded
    P('pod')); static per-pod indexing makes SPMD insert the cross-pod
    gathers of the *packed* data — the compressed wire.
    """
    def one(g, p, s, raw):
        if raw is not None:
            return raw
        total = None
        for i in range(n_pods):
            d = _dequant_lastdim(p[i], s[i], bits, g.shape)
            total = d if total is None else total + d
        return (total / n_pods).astype(g.dtype)

    return jax.tree.map(
        one, grads_like, planes, scales, raw_means,
        is_leaf=lambda x: x is None)


def init_residuals(params) -> object:
    """Error-feedback state: one f32 residual per param (pod-local)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compressed_bytes_per_param(bits: int, block: int = BLOCK) -> float:
    """Wire bytes per parameter for the compressed exchange."""
    return bits / 8 + 4.0 / block


# ---------------------------------------------------------------------------
# Wire-byte accounting (host side — the exchange itself runs traced)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeStats:
    """Analytic per-exchange wire accounting for one gradient pytree.

    ``quantize_tree``/``dequant_mean_tree`` execute inside traced SPMD
    regions where obs must not record (PR-6 rule), so the byte accounting
    is computed here from leaf shapes alone — exact, because the codec's
    output sizes are static functions of shape and ``bits`` — and published
    by the *caller* outside the jit boundary, once per exchange.
    """
    bits: int
    compressed_leaves: int
    raw_leaves: int
    raw_bytes: int          # what an uncompressed f32 exchange would move
    wire_bytes: int         # planes + scales, plus raw leaves verbatim

    @property
    def reduction(self) -> float:
        return self.raw_bytes / self.wire_bytes if self.wire_bytes else 0.0

    def publish(self, **labels) -> None:
        """Emit ``collectives/*`` series (no-op when obs is disabled)."""
        if not obs.enabled():
            return
        lb = dict(labels, bits=self.bits)
        obs.counter_inc("collectives/exchanges", 1, **lb)
        obs.counter_inc("collectives/raw_bytes", self.raw_bytes, **lb)
        obs.counter_inc("collectives/wire_bytes", self.wire_bytes, **lb)
        obs.counter_inc("collectives/leaves", self.compressed_leaves,
                        kind="compressed", **lb)
        obs.counter_inc("collectives/leaves", self.raw_leaves,
                        kind="raw_fallback", **lb)
        obs.gauge_set("collectives/reduction", self.reduction, **lb)


def exchange_stats(tree, bits: int) -> ExchangeStats:
    """Wire accounting for exchanging ``tree`` at ``bits`` (shapes only)."""
    compressed = raw = 0
    raw_bytes = wire_bytes = 0
    for g in jax.tree.leaves(tree):
        size = int(g.size)
        raw_bytes += size * 4
        if compressible(g):
            compressed += 1
            wire_bytes += size * bits // 8 + size // BLOCK * 4
        else:
            raw += 1
            wire_bytes += size * 4
    return ExchangeStats(bits=bits, compressed_leaves=compressed,
                         raw_leaves=raw, raw_bytes=raw_bytes,
                         wire_bytes=wire_bytes)
