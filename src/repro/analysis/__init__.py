"""Static layout/access-pattern linter for the MARS repro.

Three pass families, one findings model, one CLI
(``python -m repro.analysis``):

* ``access`` — compiled-HLO access patterns: redundant entry traffic
  vs the irredundant byte model (ACC101), non-contiguous innermost
  access on off-chip residents (ACC102), pack-width alignment (ACC103);
* ``obs_discipline`` — AST proof that no ``repro.obs`` recording call
  is reachable inside a traced function (OBS201);
* ``layout_invariants`` — solved layouts over the config zoo are valid
  permutations with honest burst accounting (LAY301/LAY302), MARS
  partitions hold (LAY303), codec bit format stays in bounds (LAY304).

Findings gate via a fingerprint suppression baseline
(``baseline.json``, kept empty) and publish as ``analysis/*`` obs
series.  See ``README.md`` in this package for the rule catalog.
"""
from .findings import Finding, SEVERITIES  # noqa: F401
