"""CLI: ``python -m repro.analysis`` — run the linter, gate on findings.

Exit codes: 0 clean (or all new findings below ``--fail-on``), 1 new
findings at/above the threshold, 2 selftest failure.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import runner
from .findings import (DEFAULT_BASELINE, Finding, severity_rank,
                       write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static layout/access-pattern/obs-discipline linter: "
                    "proves irredundancy, contiguity and obs discipline "
                    "before anything runs.")
    ap.add_argument("--root", default=runner.DEFAULT_ROOT,
                    help="source tree for the obs-discipline pass")
    ap.add_argument("--fail-on", choices=("error", "warning", "info"),
                    default="warning",
                    help="exit nonzero when a NEW finding at/above this "
                         "severity exists (default: warning)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as suppressed and exit 0")
    ap.add_argument("--no-access", action="store_true",
                    help="skip the jax-lowering access pass (host-only "
                         "table checks still run)")
    ap.add_argument("--json", help="also write the report as JSON")
    ap.add_argument("--selftest", action="store_true",
                    help="inject one violation per rule family and verify "
                         "every pass fires")
    args = ap.parse_args(argv)

    if args.selftest:
        st = runner.selftest()
        for name, ok in sorted(st["fired"].items()):
            print(f"selftest {name}: {'fired' if ok else 'MISSED'}")
        print(f"selftest: {'ok' if st['ok'] else 'FAILED'}")
        return 0 if st["ok"] else 2

    report = runner.run_all(root=args.root, baseline_path=args.baseline,
                            with_access=not args.no_access)
    print(runner.render_report(report))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if args.write_baseline:
        findings = [Finding(**{k: f[k] for k in
                               ("rule", "severity", "location", "message",
                                "pass_name")})
                    for f in report["findings"]]
        write_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} suppression(s) to {args.baseline}")
        return 0

    threshold = severity_rank(args.fail_on)
    gating = [f for f in report["new"]
              if severity_rank(f["severity"]) <= threshold]
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
