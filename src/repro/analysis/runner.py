"""Multi-pass orchestration for ``repro.analysis``.

``run_all`` executes the three pass families, applies the suppression
baseline, and returns a report dict (same JSON-serializable shape idiom
as ``launch.audit``).  ``publish_report`` emits ``analysis/*`` series so
``repro.obs.regress`` gates finding counts per PR, and ``selftest``
injects one violation per rule family and verifies each pass actually
fires — the analyzer equivalent of audit's ``--perturb-analytic``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from . import access, layout_invariants, obs_discipline
from .findings import (Finding, load_baseline, sort_findings,
                       split_by_baseline)

#: source tree the obs-discipline pass walks by default
DEFAULT_ROOT = "src/repro"


def run_all(root: str = DEFAULT_ROOT,
            baseline_path: Optional[str] = None,
            with_access: bool = True) -> dict:
    """Run every pass; split findings against the suppression baseline.

    ``with_access=False`` skips the access-pattern pass (the only one
    that needs jax to lower kernels) for fast host-only checks.
    """
    per_pass: Dict[str, List[Finding]] = {}
    if with_access:
        per_pass[access.PASS_NAME] = access.run_pass()
    else:
        per_pass[access.PASS_NAME] = access.check_data_types()
    per_pass[obs_discipline.PASS_NAME] = obs_discipline.analyze_tree(root)
    per_pass[layout_invariants.PASS_NAME] = layout_invariants.run_pass()

    findings = sort_findings(
        [f for fs in per_pass.values() for f in fs])
    baseline = load_baseline(baseline_path) if baseline_path else (
        load_baseline())
    new, suppressed = split_by_baseline(findings, baseline)
    return {
        "findings": [f.to_dict() for f in findings],
        "new": [f.to_dict() for f in new],
        "suppressed": [f.to_dict() for f in suppressed],
        "per_pass": {k: len(v) for k, v in per_pass.items()},
        "n_findings": len(findings),
        "n_new": len(new),
        "n_suppressed": len(suppressed),
    }


def worst_new_severity(report: dict) -> Optional[str]:
    sevs = [f["severity"] for f in report["new"]]
    for s in ("error", "warning", "info"):
        if s in sevs:
            return s
    return None


def render_report(report: dict) -> str:
    lines = []
    for f in report["new"]:
        lines.append(f"{f['severity'].upper():7s} {f['rule']} "
                     f"{f['location']}: {f['message']}")
    for f in report["suppressed"]:
        lines.append(f"suppressed {f['rule']} {f['location']} "
                     f"[{f['fingerprint']}]")
    per = ", ".join(f"{k}={v}" for k, v in sorted(
        report["per_pass"].items()))
    lines.append(f"analysis: {report['n_new']} new, "
                 f"{report['n_suppressed']} suppressed ({per})")
    return "\n".join(lines)


def publish_report(report: dict) -> None:
    """Emit ``analysis/*`` series (no-op when obs is disabled).

    ``analysis/new_findings`` must stay at its baseline of 0 — the
    regression gate compares it exactly, so a PR that introduces a
    violation fails the bench gate even if nobody ran the CLI.
    """
    from repro.obs import instrument as obs
    if not obs.enabled():
        return
    obs.counter_inc("analysis/findings", report["n_findings"])
    obs.counter_inc("analysis/new_findings", report["n_new"])
    obs.counter_inc("analysis/suppressed", report["n_suppressed"])
    for pass_name, n in sorted(report["per_pass"].items()):
        obs.counter_inc("analysis/pass_findings", n, pass_name=pass_name)


# ---------------------------------------------------------------------------
# Selftest: one injected violation per rule family
# ---------------------------------------------------------------------------

#: hand-written HLO: ENTRY reads an f32[1024] param and writes it twice
#: (concat with itself) — 8192 B of writes against a 4096 B analytic charge
REDUNDANT_HLO = """\
HloModule redundant

ENTRY %main (p0: f32[1024]) -> f32[2048] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %cat = f32[2048]{0} concatenate(f32[1024]{0} %p0, f32[1024]{0} %p0), dimensions={0}
}
"""

#: hand-written HLO: stride-2 innermost slice of an off-chip param
STRIDED_HLO = """\
HloModule strided

ENTRY %main (p0: f32[64,64]) -> f32[64,32] {
  %p0 = f32[64,64]{1,0} parameter(0)
  ROOT %sl = f32[64,32]{1,0} slice(f32[64,64]{1,0} %p0), slice={[0:64:1], [0:64:2]}
}
"""

#: source fixture: obs recording reachable through a jitted helper
OBS_UNDER_JIT_SRC = """\
import jax
from repro.obs import instrument as obs

def helper(x):
    obs.counter_inc("bad/inside_trace", 1)
    return x

@jax.jit
def kernel(x):
    return helper(x)
"""


def selftest() -> dict:
    """Inject one violation per rule family; report which passes fired.

    Returns ``{"ok": bool, "fired": {injection: bool}}`` — ``ok`` only
    when every injected violation was caught.  This is the proof that a
    green analyzer run means "checked and clean", not "checked nothing".
    """
    from repro.core import layout, mars, stencil

    fired: Dict[str, bool] = {}

    # 1. redundant transfer (ACC101)
    case = access.KernelCase("selftest/redundant", REDUNDANT_HLO,
                             read_bytes=4096, write_bytes=4096)
    fs = access.check_redundancy(case)
    fired["redundant-transfer"] = any(
        f.rule == "ACC101" and f.severity == "error" for f in fs)

    # 2. strided innermost access (ACC102)
    case = access.KernelCase("selftest/strided", STRIDED_HLO,
                             read_bytes=16384, write_bytes=8192)
    fs = access.check_contiguity(case)
    fired["strided-access"] = any(f.rule == "ACC102" for f in fs)

    # 3. misaligned pack width (ACC103): 5 bits does not tile 32
    case = access.KernelCase("selftest/misaligned", REDUNDANT_HLO,
                             read_bytes=8192, write_bytes=8192,
                             pack_bits=5, pack_block=48)
    fs = access.check_pack_alignment(case)
    fired["misaligned-pack"] = sum(f.rule == "ACC103" for f in fs) == 2

    # 4. obs recording under jit (OBS201)
    nodes = obs_discipline.scan_source(OBS_UNDER_JIT_SRC, "selftest_obs.py")
    fs = obs_discipline.run_pass(nodes)
    fired["obs-under-jit"] = any(f.rule == "OBS201" for f in fs)

    # 5. invalid layout permutation (LAY301): duplicate an index
    a = mars.analyze(stencil.SPECS["jacobi-1d"]((6, 6)))
    good = layout.layout_for_analysis(a)
    bad_order = list(good.order)
    bad_order[0] = bad_order[1]
    import dataclasses
    bad = dataclasses.replace(good, order=tuple(bad_order))
    fs = layout_invariants.check_layout("jacobi-1d", (6, 6), a, result=bad)
    fired["invalid-permutation"] = any(f.rule == "LAY301" for f in fs)

    # 6. burst-count lie (LAY302)
    lied = dataclasses.replace(good, read_bursts=good.read_bursts + 1)
    fs = layout_invariants.check_layout("jacobi-1d", (6, 6), a, result=lied)
    fired["burst-miscount"] = any(f.rule == "LAY302" for f in fs)

    return {"ok": all(fired.values()), "fired": fired}
