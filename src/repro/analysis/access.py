"""Access-pattern pass: redundancy, contiguity, pack alignment — pre-run.

Mirrors the kernel grid of ``launch.audit.kernel_io_audit`` (same shapes,
same lowering) but asks different questions of the optimized HLO:

* **ACC101 redundant traffic** (error): the compiled ENTRY moves more
  bytes than the analytic irredundant charge (``ops.*_io_bytes`` — "read
  every input once, write every output once").  Excess read or write
  traffic means the lowering re-materializes off-chip data the layout
  was supposed to make irredundant.
* **ACC102 non-contiguous innermost access** (warning): a ``gather``,
  stride>1 innermost ``slice``, innermost-moving ``transpose`` or
  ``reverse`` applied to ENTRY-parameter-derived data.  Off-chip
  residents are charged by the AXI burst model in ``core/transfer.py``;
  breaking the innermost dimension turns one long burst into per-element
  bursts, and the message quotes the cycle inflation for the shape.
* **ACC103 misaligned pack width** (error): a pack/unpack case whose bit
  width does not tile the 32-bit plane word (``32 % bits != 0``) or whose
  block does not fill whole plane words (``block % 32 != 0``); plus the
  static ``DATA_TYPES`` table check that every container width equals
  ``packing.padded_width(nbits)``.

The pass needs jax to lower the cases (imports deferred like audit's);
the rule logic itself is pure text/arith over ``launch.hlo_text`` parses
so fixtures can exercise it HLO-in, findings-out.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Sequence, Set, Tuple

from repro.core.packing import DATA_TYPES, padded_width
from repro.core.transfer import TransferModel
from repro.launch import hlo_text

from .findings import Finding

PASS_NAME = "access-pattern"

#: exact tolerance for byte comparisons (float round-off only)
BYTES_RTOL = 1e-9

#: ops that permute or scatter their operand's address stream
_NONCONTIG_OPS = ("gather", "transpose", "reverse", "slice")


@dataclasses.dataclass
class KernelCase:
    """One lowered kernel + its analytic irredundant byte charge."""
    name: str
    hlo: str
    read_bytes: int
    write_bytes: int
    pack_bits: int = 0     # plane-pack bit width (0 = not a packing kernel)
    pack_block: int = 0


def builtin_cases() -> List[KernelCase]:
    """The audit kernel grid, lowered (requires jax)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    n, block = 256, 32
    rows, d = 64, 64
    jn = 4096
    t_steps = 4

    def lower(fn, *specs):
        return jax.jit(fn).lower(*specs).compile().as_text()

    s = jax.ShapeDtypeStruct
    cases: List[KernelCase] = []
    for bits in (4, 8):
        r, w = ops.pack_io_bytes(n, block, bits)
        cases.append(KernelCase(
            f"kernel/pack/bits{bits}",
            lower(lambda q, b=bits: ref.pack_ref(q, b),
                  s((n, block), jnp.int32)),
            r, w, pack_bits=bits, pack_block=block))
        r, w = ops.unpack_io_bytes(n, block, bits)
        cases.append(KernelCase(
            f"kernel/unpack/bits{bits}",
            lower(lambda p, b=bits: ref.unpack_ref(p, b, block),
                  s((n, block // 32 * bits), jnp.uint32)),
            r, w, pack_bits=bits, pack_block=block))
        r, w = ops.kv_quant_io_bytes(rows, d, bits)
        cases.append(KernelCase(
            f"kernel/kv_quant/bits{bits}",
            lower(lambda x, b=bits: ref.kv_quant_ref(x, b),
                  s((rows, d), jnp.float32)),
            r, w))
        cd = d if bits == 8 else d // 2
        r, w = ops.kv_dequant_io_bytes(rows, d, bits)
        cases.append(KernelCase(
            f"kernel/kv_dequant/bits{bits}",
            lower(lambda c, sc, b=bits: ref.kv_dequant_ref(c, sc, b),
                  s((rows, cd), jnp.int8), s((rows,), jnp.float32)),
            r, w))
    r, w = ops.jacobi_io_bytes(jn)
    cases.append(KernelCase(
        "kernel/jacobi1d",
        lower(lambda x: ref.jacobi_chunked_ref(x, t_steps),
              s((jn,), jnp.float32)),
        r, w))
    return cases


# ---------------------------------------------------------------------------
# ACC101: redundant entry traffic
# ---------------------------------------------------------------------------

def check_redundancy(case: KernelCase) -> List[Finding]:
    from repro.launch import hlo_walk

    got_r, got_w = hlo_walk.entry_io_bytes(case.hlo)
    findings = []
    for kind, got, want in (("read", got_r, case.read_bytes),
                            ("write", got_w, case.write_bytes)):
        if got > want * (1 + BYTES_RTOL):
            findings.append(Finding(
                rule="ACC101", severity="error",
                location=case.name,
                message=(f"compiled ENTRY {kind}s {got} B but the "
                         f"irredundant model charges {want} B "
                         f"(+{got - want} B redundant {kind} traffic)"),
                pass_name=PASS_NAME))
        elif got < want * (1 - BYTES_RTOL):
            findings.append(Finding(
                rule="ACC101", severity="info",
                location=case.name,
                message=(f"compiled ENTRY {kind}s {got} B, below the "
                         f"analytic charge {want} B — model overcharges"),
                pass_name=PASS_NAME))
    return findings


# ---------------------------------------------------------------------------
# ACC102: non-contiguous innermost access on off-chip residents
# ---------------------------------------------------------------------------

def _param_derived(instrs: Sequence[hlo_text.Instr]) -> Set[str]:
    """Names transitively computed from ENTRY parameters."""
    derived: Set[str] = {i.name for i in instrs if i.op == "parameter"}
    changed = True
    while changed:
        changed = False
        for ins in instrs:
            if ins.name in derived:
                continue
            if any(op in derived for op in hlo_text.operand_names(ins.rhs)):
                derived.add(ins.name)
                changed = True
    return derived


def _innermost_violation(ins: hlo_text.Instr) -> str:
    """Reason this instruction breaks innermost contiguity, or ''. """
    if ins.op == "gather":
        return "gather indexes off-chip data element-wise"
    meta = ins.rhs.split(" metadata")[0]
    if ins.op == "slice":
        m = re.search(r"slice=\{(.*?)\}", meta)
        if m:
            dims = re.findall(r"\[(\d+):(\d+):?(\d*)\]", m.group(1))
            if dims:
                stride = int(dims[-1][2] or 1)
                if stride > 1:
                    return f"innermost slice stride {stride}"
        return ""
    if ins.op in ("transpose", "reverse"):
        m = re.search(r"dimensions=\{([\d,]*)\}", meta)
        if not m:
            return ""
        dims = [int(d) for d in m.group(1).split(",") if d]
        _, shapes = hlo_text.shapes_info(ins.result_text)
        rank = len(shapes[0][1]) if shapes else len(dims)
        if ins.op == "transpose" and dims and dims[-1] != rank - 1:
            return f"transpose moves innermost dim (permutation {dims})"
        if ins.op == "reverse" and dims and (rank - 1) in dims:
            return "reverse walks the innermost dim backwards"
    return ""


def _burst_quote(ins: hlo_text.Instr, model: TransferModel) -> str:
    """Cycle inflation of per-element bursts vs one contiguous run."""
    _, shapes = hlo_text.shapes_info(ins.result_text)
    if not shapes:
        return ""
    dt, dims = shapes[0]
    elems = 1
    for d in dims:
        elems *= d
    ebits = 8 * hlo_text.DTYPE_BYTES.get(dt, 4)
    contig = model.transaction_cycles(elems * ebits)
    scattered = elems * model.transaction_cycles(ebits)
    return (f"; burst model: {contig} cycles contiguous vs "
            f"{scattered} scattered ({scattered / max(contig, 1):.1f}x)")


def check_contiguity(case: KernelCase,
                     model: TransferModel = None) -> List[Finding]:
    model = model or TransferModel()
    comps = hlo_text.parse_computations(case.hlo)
    entry = hlo_text.find_entry(case.hlo, comps)
    instrs = comps.get(entry or "", [])
    derived = _param_derived(instrs)

    findings = []

    def flag(ins: hlo_text.Instr, reason: str, where: str) -> None:
        findings.append(Finding(
            rule="ACC102", severity="warning",
            location=f"{case.name}/{where}",
            message=(f"non-contiguous innermost access: {ins.op} %"
                     f"{ins.name} — {reason}{_burst_quote(ins, model)}"),
            pass_name=PASS_NAME))

    for ins in instrs:
        if ins.op in _NONCONTIG_OPS:
            if not any(op in derived
                       for op in hlo_text.operand_names(ins.rhs)):
                continue  # on-chip temporary, not an off-chip stream
            reason = _innermost_violation(ins)
            if reason:
                flag(ins, reason, "entry")
        elif ins.op == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", ins.rhs)
            body = comps.get(m.group(1), []) if m else []
            if not any(op in derived
                       for op in hlo_text.operand_names(ins.rhs)):
                continue
            for bins in body:
                if bins.op in _NONCONTIG_OPS:
                    reason = _innermost_violation(bins)
                    if reason:
                        flag(bins, reason, m.group(1))
    return findings


# ---------------------------------------------------------------------------
# ACC103: pack-width alignment
# ---------------------------------------------------------------------------

def check_pack_alignment(case: KernelCase) -> List[Finding]:
    findings = []
    bits, block = case.pack_bits, case.pack_block
    if bits:
        if 32 % bits != 0:
            findings.append(Finding(
                rule="ACC103", severity="error", location=case.name,
                message=(f"pack width {bits} does not tile the 32-bit "
                         "plane word — codes straddle word boundaries"),
                pass_name=PASS_NAME))
        if block and block % 32 != 0:
            findings.append(Finding(
                rule="ACC103", severity="error", location=case.name,
                message=(f"block {block} does not fill whole 32-bit plane "
                         "words (block % 32 != 0)"),
                pass_name=PASS_NAME))
    return findings


def check_data_types() -> List[Finding]:
    """Static ``DATA_TYPES`` container-width consistency (no jax)."""
    findings = []
    for name, (nbits, width) in sorted(DATA_TYPES.items()):
        want = padded_width(nbits)
        if width != want:
            findings.append(Finding(
                rule="ACC103", severity="error",
                location=f"core/packing.py:DATA_TYPES[{name}]",
                message=(f"container width {width} != padded_width({nbits})"
                         f" == {want}"),
                pass_name=PASS_NAME))
        if nbits > width:
            findings.append(Finding(
                rule="ACC103", severity="error",
                location=f"core/packing.py:DATA_TYPES[{name}]",
                message=f"nbits {nbits} exceeds container width {width}",
                pass_name=PASS_NAME))
    return findings


# ---------------------------------------------------------------------------
# pass driver
# ---------------------------------------------------------------------------

def run_pass(cases: Sequence[KernelCase] = None) -> List[Finding]:
    if cases is None:
        cases = builtin_cases()
    findings: List[Finding] = check_data_types()
    for case in cases:
        findings.extend(check_redundancy(case))
        findings.extend(check_contiguity(case))
        findings.extend(check_pack_alignment(case))
    return findings
