"""Obs-discipline pass: no ``repro.obs`` recording reachable under a trace.

The repo-wide rule (``src/repro/obs/README.md``, PR 6) is *record around
``jax.jit``-traced calls, never inside them*: Python side effects inside a
traced function run once per compile, not once per call, so a counter
bumped there silently under-counts (and pollutes the trace).  Until now
the rule was enforced by convention; this pass proves it statically.

Model (pure ``ast``, no imports executed):

* every ``def``/``lambda`` in the tree is a node; calls to names we can
  resolve (same module, ``self.``-methods, ``module.attr`` through the
  import table) are edges;
* a node is a **traced root** when it is decorated with ``jax.jit`` /
  ``pallas_call`` (including through ``functools.partial``) or passed to a
  tracing combinator (``jax.jit``, ``pallas_call``, ``lax.scan`` /
  ``while_loop`` / ``cond`` / ``fori_loop``, ``vmap``, ``grad``,
  ``value_and_grad``, ``shard_map``, ``checkpoint``/``remat``);
* a **recording site** is a call of the obs facade (``counter_inc``,
  ``gauge_set``, ``hist_observe``, ``span``, ``instrumented``) through any
  alias of ``repro.obs`` / ``repro.obs.instrument``.

Rule OBS201 fires for every recording site reachable from a traced root,
with the root-to-site path in the message.  Resolution is deliberately
conservative: an edge we cannot resolve is dropped, so the pass
under-approximates reachability and never invents call chains.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding

PASS_NAME = "obs-discipline"

#: obs facade entry points whose execution records (or opens a span)
RECORDING_APIS = ("counter_inc", "gauge_set", "hist_observe", "span",
                  "instrumented")

#: dotted suffixes that identify the obs facade modules
OBS_MODULES = ("repro.obs", "repro.obs.instrument")

#: callables whose function-valued arguments are traced by jax
TRACING_CALLABLES = (
    "jax.jit", "jit", "pallas_call", "pl.pallas_call",
    "jax.lax.scan", "lax.scan", "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.cond", "lax.cond", "jax.lax.fori_loop", "lax.fori_loop",
    "jax.vmap", "vmap", "jax.grad", "grad", "jax.value_and_grad",
    "value_and_grad", "shard_map", "jax.shard_map", "jax.checkpoint",
    "jax.remat", "jax.eval_shape",
)


@dataclasses.dataclass
class _FuncNode:
    """One function/lambda: its calls, recording sites, and trace roots."""
    key: Tuple[str, str]                 # (relpath, qualname)
    lineno: int
    traced_reason: Optional[str] = None
    # resolved callee keys with call-site line numbers
    calls: List[Tuple[Tuple[str, str], int]] = dataclasses.field(
        default_factory=list)
    # (api name, lineno) of direct obs recording calls
    recording: List[Tuple[str, int]] = dataclasses.field(default_factory=list)


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _ModuleScan(ast.NodeVisitor):
    """Single-module collection of functions, imports, and classes."""

    def __init__(self, relpath: str, tree: ast.Module):
        self.relpath = relpath
        self.imports: Dict[str, str] = {}      # local alias -> dotted target
        self.nodes: Dict[Tuple[str, str], _FuncNode] = {}
        self._scope: List[str] = []
        self._class: List[str] = []
        self._lambda_n = 0
        self.visit(tree)

    # -- imports -----------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.imports[a.asname or a.name.split(".")[0]] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if node.level:  # relative: anchor under repro heuristically
            pkg = _package_of(self.relpath, node.level)
            mod = f"{pkg}.{mod}" if mod else pkg
        for a in node.names:
            self.imports[a.asname or a.name] = f"{mod}.{a.name}"

    # -- scopes ------------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class.append(node.name)
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()
        self._class.pop()

    def _qual(self, name: str) -> str:
        return ".".join(self._scope + [name]) if self._scope else name

    def _enter_function(self, name: str, node: ast.AST,
                        decorators: Sequence[ast.AST]) -> None:
        qual = self._qual(name)
        fn = _FuncNode(key=(self.relpath, qual), lineno=node.lineno)
        for dec in decorators:
            hit = _tracing_name_in(dec, self.imports)
            if hit:
                fn.traced_reason = f"decorated with {hit}"
        self.nodes[fn.key] = fn
        self._scope.append(name)
        body = node.body if isinstance(node.body, list) else [node.body]
        _BodyScan(self, fn).scan(body)
        self._scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._enter_function(node.name, node, node.decorator_list)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._enter_function(node.name, node, node.decorator_list)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._lambda_n += 1
        self._enter_function(f"<lambda@{node.lineno}>", node, ())


class _BodyScan(ast.NodeVisitor):
    """Scan one function body, stopping at nested function boundaries."""

    def __init__(self, mod: _ModuleScan, fn: _FuncNode):
        self.mod = mod
        self.fn = fn

    def scan(self, body: Iterable[ast.AST]) -> None:
        for stmt in body:
            self.visit(stmt)

    # nested definitions are their own nodes (visited via _ModuleScan)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.mod.visit_FunctionDef(node)
        self._note_local_def(node.name, node.lineno)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self.mod.visit_AsyncFunctionDef(node)
        self._note_local_def(node.name, node.lineno)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self.mod.visit_Lambda(node)

    def _note_local_def(self, name: str, lineno: int) -> None:
        # calling a nested def from this body is an edge to it
        qual = ".".join(self.mod._scope + [name])
        self.fn.calls.append(((self.mod.relpath, qual), lineno))

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        # 1. obs recording site?
        api = _recording_api(dotted, self.mod.imports)
        if api:
            self.fn.recording.append((api, node.lineno))
        # 2. tracing combinator: its function-valued args become traced roots
        if dotted and _is_tracing_callable(dotted, self.mod.imports):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                self._mark_traced(arg, dotted)
        # 3. ordinary call edge
        elif dotted:
            callee = self._resolve(dotted)
            if callee:
                self.fn.calls.append((callee, node.lineno))
        self.generic_visit(node)

    def _mark_traced(self, arg: ast.AST, via: str) -> None:
        if isinstance(arg, ast.Lambda):
            qual = ".".join(self.mod._scope + [f"<lambda@{arg.lineno}>"])
            key = (self.mod.relpath, qual)
            # the lambda node is created when generic_visit descends into it
            self._pending_trace = getattr(self, "_pending_trace", [])
            self._pending_trace.append((key, via))
            self.mod._deferred_traced.append((key, via))
            return
        dotted = _dotted(arg)
        if not dotted:
            return
        callee = self._resolve(dotted)
        if callee:
            self.mod._deferred_traced.append((callee, via))

    def _resolve(self, dotted: str) -> Optional[Tuple[str, str]]:
        """Best-effort: dotted call name -> function node key."""
        mod = self.mod
        parts = dotted.split(".")
        # self.method -> method of the enclosing class
        if parts[0] == "self" and len(parts) == 2 and mod._class:
            return (mod.relpath, f"{mod._class[-1]}.{parts[1]}")
        # bare name: function in an enclosing scope chain, then module level
        if len(parts) == 1:
            scope = list(mod._scope)
            while True:
                qual = ".".join(scope + parts)
                if (mod.relpath, qual) in mod.nodes or scope == []:
                    return (mod.relpath, qual)
                scope.pop()
        # alias.attr through the import table -> other repro module
        target = mod.imports.get(parts[0])
        if target and "repro" in target:
            relmod = _module_to_relpath(target)
            if relmod:
                return (relmod, ".".join(parts[1:]))
        return None


# ---------------------------------------------------------------------------
# name helpers
# ---------------------------------------------------------------------------

def _package_of(relpath: str, level: int) -> str:
    """Dotted package of a relative import from ``relpath``."""
    parts = relpath.replace(os.sep, "/").split("/")[:-1]
    if level > 1:
        parts = parts[: -(level - 1)] if level - 1 <= len(parts) else []
    return ".".join(parts)


def _module_to_relpath(dotted: str) -> Optional[str]:
    """'x.y.repro.core.mars' (or 'repro.core.mars') -> 'repro/core/mars.py'."""
    parts = dotted.split(".")
    if "repro" not in parts:
        return None
    parts = parts[parts.index("repro"):]
    return "/".join(parts) + ".py"


def _recording_api(dotted: Optional[str],
                   imports: Dict[str, str]) -> Optional[str]:
    """The obs api name if this dotted callee is a recording entry point."""
    if not dotted:
        return None
    parts = dotted.split(".")
    if parts[-1] not in RECORDING_APIS:
        return None
    if len(parts) == 1:
        target = imports.get(parts[0], "")
        return parts[-1] if _is_obs_module(target.rsplit(".", 1)[0]) else None
    base = imports.get(parts[0], parts[0])
    prefix = ".".join([base] + parts[1:-1])
    return parts[-1] if _is_obs_module(prefix) else None


def _is_obs_module(dotted: str) -> bool:
    return any(dotted == m or dotted.endswith("." + m) or
               dotted.endswith(m.split(".")[-1]) and "obs" in dotted
               for m in OBS_MODULES)


def _is_tracing_callable(dotted: str, imports: Dict[str, str]) -> bool:
    parts = dotted.split(".")
    base = imports.get(parts[0], parts[0])
    full = ".".join([base] + parts[1:])
    for t in TRACING_CALLABLES:
        if dotted == t or full == t or full.endswith("." + t):
            return True
    return False


def _tracing_name_in(dec: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """A jit/pallas name anywhere in a decorator expression, if present."""
    for sub in ast.walk(dec):
        dotted = _dotted(sub)
        if dotted and _is_tracing_callable(dotted, imports):
            return dotted
    return None


# ---------------------------------------------------------------------------
# pass driver
# ---------------------------------------------------------------------------

def scan_tree(root: str,
              exclude: Sequence[str] = ("analysis",)
              ) -> Dict[Tuple[str, str], _FuncNode]:
    """Parse every .py under ``root`` into the project call-graph nodes."""
    nodes: Dict[Tuple[str, str], _FuncNode] = {}
    rootname = os.path.basename(os.path.normpath(root))
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d != "__pycache__" and d not in exclude]
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.join(
                rootname, os.path.relpath(path, root)).replace(os.sep, "/")
            nodes.update(scan_file(path, rel))
    return nodes


def scan_file(path: str,
              relpath: Optional[str] = None) -> Dict[Tuple[str, str],
                                                     _FuncNode]:
    with open(path) as f:
        src = f.read()
    return scan_source(src, relpath or os.path.basename(path))


def scan_source(src: str, rel: str) -> Dict[Tuple[str, str], _FuncNode]:
    """Scan python source text (fixtures/selftests need no real file)."""
    tree = ast.parse(src, filename=rel)
    scan = _ModuleScan.__new__(_ModuleScan)
    scan.relpath = rel
    scan.imports = {}
    scan.nodes = {}
    scan._scope = []
    scan._class = []
    scan._lambda_n = 0
    scan._deferred_traced = []
    scan.visit(tree)
    for key, via in scan._deferred_traced:
        node = scan.nodes.get(key)
        if node is not None and node.traced_reason is None:
            node.traced_reason = f"passed to {via}"
    return scan.nodes


def run_pass(nodes: Dict[Tuple[str, str], _FuncNode]) -> List[Finding]:
    """OBS201 for every recording site reachable from a traced root."""
    findings: List[Finding] = []
    roots = [k for k, n in nodes.items() if n.traced_reason]
    reported: Set[Tuple[Tuple[str, str], int]] = set()
    for root_key in sorted(roots):
        stack: List[Tuple[Tuple[str, str], Tuple[str, ...]]] = [
            (root_key, (f"{root_key[0]}::{root_key[1]}",))]
        seen: Set[Tuple[str, str]] = set()
        while stack:
            key, path = stack.pop()
            if key in seen:
                continue
            seen.add(key)
            node = nodes.get(key)
            if node is None:
                continue
            for api, lineno in node.recording:
                site = (key, lineno)
                if site in reported:
                    continue
                reported.add(site)
                chain = " -> ".join(path)
                findings.append(Finding(
                    rule="OBS201", severity="error",
                    location=f"{key[0]}:{lineno}",
                    message=(f"obs.{api} reachable inside a traced function "
                             f"({nodes[root_key].traced_reason}; via "
                             f"{chain}) — record around the jitted call, "
                             "never inside it"),
                    pass_name=PASS_NAME))
            for callee, _line in node.calls:
                if callee not in seen:
                    stack.append((callee,
                                  path + (f"{callee[0]}::{callee[1]}",)))
    return findings


def analyze_tree(root: str) -> List[Finding]:
    return run_pass(scan_tree(root))
