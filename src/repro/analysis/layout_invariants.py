"""Layout/codec invariant pass over the stencil config zoo.

For every ``(benchmark, tile_sizes)`` pair in ``core/stencil.ZOO`` —
the same grid Table 1 is validated on — this pass re-derives the MARS
analysis and proves the solved layout and the codec's bit format hold
their invariants *before* anything is generated or run:

* **LAY301 invalid permutation** (error): the solved layout order must
  be a permutation of ``range(n_out)`` — a repeated or missing MARS
  index means the address generator would drop or duplicate data.
* **LAY302 burst accounting** (error): the reported ``read_bursts``
  must equal ``count_bursts(order, consumed_sets)`` recomputed from
  scratch, ``write_bursts`` must be 1 (output MARS are laid out in
  layout order, one contiguous stream), and for small instances
  (``n_out <= 8``) the burst count must match ``brute_force_layout``'s
  optimum — the solver may not silently go sub-optimal where
  exhaustive search is feasible.
* **LAY303 partition violation** (error): ``mars.check_partition`` —
  every tile point in exactly one consumed MARS, no consumer-less MARS
  (irredundancy + atomicity, §3).
* **LAY304 codec bounds** (error): for every paper data type, the
  compressed bit format stays inside its envelope: the length field
  ``F = length_field_bits(nbits)`` can index every magnitude length in
  ``[0, nbits]``; a synthetic per-MARS stream's markers are strictly
  increasing, word+bit aligned (``0 <= fine < bus_bits``), inside the
  stream, and each MARS independently seek-decodes back to its input.

Pure numpy/stdlib — no jax needed, so this pass runs anywhere.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import layout, mars, stencil
from repro.core.compression import (compress_mars_stream, decompress_mars,
                                    length_field_bits)
from repro.core.packing import DATA_TYPES

from .findings import Finding

PASS_NAME = "layout-invariants"

#: brute-force optimality cross-check limit (8! orders)
BRUTE_LIMIT = 8


def _loc(name: str, tile_sizes: Sequence[int]) -> str:
    return f"stencil:{name}@{'x'.join(map(str, tile_sizes))}"


def check_layout(name: str, tile_sizes: Sequence[int],
                 analysis=None, result=None) -> List[Finding]:
    """LAY301 + LAY302 for one zoo entry.

    ``result`` injects a precomputed (possibly corrupted) LayoutResult —
    the selftest path proving the rule actually fires.
    """
    a = analysis if analysis is not None else (
        mars.analyze(stencil.SPECS[name](tuple(tile_sizes))))
    lr = result if result is not None else layout.layout_for_analysis(a)
    loc = _loc(name, tile_sizes)
    findings: List[Finding] = []

    if sorted(lr.order) != list(range(a.n_out)):
        findings.append(Finding(
            rule="LAY301", severity="error", location=loc,
            message=(f"layout order {list(lr.order)} is not a permutation "
                     f"of range({a.n_out}) — address generator would "
                     "drop/duplicate MARS"),
            pass_name=PASS_NAME))
        return findings  # burst accounting is meaningless on a non-perm

    consumed_sets = list(a.consumed.values())
    recount = layout.count_bursts(lr.order, consumed_sets)
    if lr.read_bursts != recount:
        findings.append(Finding(
            rule="LAY302", severity="error", location=loc,
            message=(f"solver reports {lr.read_bursts} read bursts but "
                     f"count_bursts(order) == {recount}"),
            pass_name=PASS_NAME))
    if lr.write_bursts != 1:
        findings.append(Finding(
            rule="LAY302", severity="error", location=loc,
            message=(f"write_bursts == {lr.write_bursts}, expected 1 "
                     "(outputs are one contiguous stream in layout order)"),
            pass_name=PASS_NAME))
    if a.n_out <= BRUTE_LIMIT:
        opt = layout.brute_force_layout(a.n_out, consumed_sets)
        if lr.read_bursts != opt.read_bursts:
            findings.append(Finding(
                rule="LAY302", severity="error", location=loc,
                message=(f"solver burst count {lr.read_bursts} != brute-"
                         f"force optimum {opt.read_bursts} (n_out="
                         f"{a.n_out} is exhaustively checkable)"),
                pass_name=PASS_NAME))
    return findings


def check_partition(name: str, tile_sizes: Sequence[int],
                    analysis=None) -> List[Finding]:
    """LAY303 for one zoo entry."""
    a = analysis if analysis is not None else (
        mars.analyze(stencil.SPECS[name](tuple(tile_sizes))))
    try:
        mars.check_partition(a)
    except AssertionError as e:
        return [Finding(
            rule="LAY303", severity="error",
            location=_loc(name, tile_sizes),
            message=f"MARS partition violated: {e}",
            pass_name=PASS_NAME)]
    return []


def check_codec(name: str, tile_sizes: Sequence[int],
                analysis=None, bus_bits: int = 64) -> List[Finding]:
    """LAY304 for one zoo entry, across every paper data type."""
    a = analysis if analysis is not None else (
        mars.analyze(stencil.SPECS[name](tuple(tile_sizes))))
    loc = _loc(name, tile_sizes)
    findings: List[Finding] = []
    sizes = [m.size for m in a.out_mars] or [1]

    for dtype, (nbits, width) in sorted(DATA_TYPES.items()):
        if nbits > 64:
            findings.append(Finding(
                rule="LAY304", severity="error", location=f"{loc}/{dtype}",
                message=f"nbits {nbits} exceeds the 64-bit codec word",
                pass_name=PASS_NAME))
            continue
        F = length_field_bits(nbits)
        if (1 << F) <= nbits:
            findings.append(Finding(
                rule="LAY304", severity="error", location=f"{loc}/{dtype}",
                message=(f"length field F={F} cannot index magnitude "
                         f"lengths up to nbits={nbits}"),
                pass_name=PASS_NAME))
        # synthetic per-MARS payloads, deterministic, full bit range
        rng = np.random.RandomState(len(name) * 7 + sum(tile_sizes))
        mask = (1 << nbits) - 1 if nbits < 64 else (1 << 64) - 1
        data = [rng.randint(0, 1 << 30, size=max(s, 1)).astype(np.uint64)
                & np.uint64(mask) for s in sizes]
        # synthetic payloads: suppress obs so the linter's probe streams
        # never leak compression/* series into a surrounding bench run
        from repro.obs import instrument as obs
        with obs.disabled_scope():
            stream = compress_mars_stream(data, nbits, bus_bits=bus_bits)
        prev_bit = -1
        for i, m in enumerate(stream.markers):
            bit = m.coarse * bus_bits + m.fine
            if not 0 <= m.fine < bus_bits:
                findings.append(Finding(
                    rule="LAY304", severity="error",
                    location=f"{loc}/{dtype}",
                    message=(f"marker {i} fine offset {m.fine} outside "
                             f"[0, bus_bits={bus_bits})"),
                    pass_name=PASS_NAME))
            if bit <= prev_bit or bit > stream.total_bits:
                findings.append(Finding(
                    rule="LAY304", severity="error",
                    location=f"{loc}/{dtype}",
                    message=(f"marker {i} bit offset {bit} not strictly "
                             f"increasing inside the {stream.total_bits}-"
                             "bit stream"),
                    pass_name=PASS_NAME))
            prev_bit = bit
        for i, arr in enumerate(data):
            got = decompress_mars(stream, i)
            if not np.array_equal(got, arr):
                findings.append(Finding(
                    rule="LAY304", severity="error",
                    location=f"{loc}/{dtype}",
                    message=(f"MARS {i} does not round-trip through "
                             "seek-decode at its marker"),
                    pass_name=PASS_NAME))
                break
    return findings


def run_pass(zoo: Optional[Dict[str, Tuple[Tuple[int, ...], ...]]] = None
             ) -> List[Finding]:
    zoo = zoo if zoo is not None else stencil.ZOO
    findings: List[Finding] = []
    for name, tiles in zoo.items():
        for ts in tiles:
            a = mars.analyze(stencil.SPECS[name](tuple(ts)))
            findings.extend(check_layout(name, ts, a))
            findings.extend(check_partition(name, ts, a))
            findings.extend(check_codec(name, ts, a))
    return findings
