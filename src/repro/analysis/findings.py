"""Findings model + suppression baseline for ``repro.analysis``.

A *finding* is one rule violation: rule id, severity, location, message.
Findings are value objects so passes stay pure (emit, never print) and the
runner owns presentation, exit codes and the suppression baseline.

The baseline file (default ``src/repro/analysis/baseline.json``) holds
fingerprints of known findings; the CLI fails only on findings *not* in
the baseline, so a violation can be suppressed explicitly (reviewed,
committed, visible in diffs) instead of silently tolerated.  The repo's
own baseline is empty — the tree is kept clean — and the workflow for a
deliberate suppression is documented in the package README.

Fingerprints hash (rule, location-without-line, message) so a finding does
not escape its suppression by drifting a few lines.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Iterable, List, Sequence, Tuple

#: severity order, most severe first
SEVERITIES = ("error", "warning", "info")


def severity_rank(severity: str) -> int:
    return SEVERITIES.index(severity)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation discovered by a pass."""
    rule: str          # catalog id, e.g. "ACC101"
    severity: str      # error | warning | info
    location: str      # "path/to/file.py:123" or "stencil:jacobi-1d@6x6"
    message: str
    pass_name: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def fingerprint(self) -> str:
        loc = self.location.rsplit(":", 1)
        base = loc[0] if len(loc) == 2 and loc[1].isdigit() else self.location
        h = hashlib.sha256(
            f"{self.rule}|{base}|{self.message}".encode()).hexdigest()
        return h[:16]

    def to_dict(self) -> Dict[str, str]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        return (f"{self.severity.upper():7s} {self.rule} "
                f"{self.location}: {self.message}")


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (severity_rank(f.severity),
                                           f.rule, f.location, f.message))


# ---------------------------------------------------------------------------
# Suppression baseline
# ---------------------------------------------------------------------------

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str = DEFAULT_BASELINE) -> Dict[str, dict]:
    """fingerprint -> recorded entry; missing file == empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    return {e["fingerprint"]: e for e in doc.get("suppressions", [])}

def write_baseline(findings: Sequence[Finding],
                   path: str = DEFAULT_BASELINE) -> None:
    """Record every given finding as suppressed (explicit refresh only)."""
    entries = [{"fingerprint": f.fingerprint, "rule": f.rule,
                "location": f.location, "message": f.message}
               for f in sort_findings(findings)]
    with open(path, "w") as f:
        json.dump({"suppressions": entries}, f, indent=1, sort_keys=True)
        f.write("\n")


def split_by_baseline(findings: Sequence[Finding],
                      baseline: Dict[str, dict]
                      ) -> Tuple[List[Finding], List[Finding]]:
    """(new findings, suppressed findings) under a loaded baseline."""
    new, suppressed = [], []
    for f in findings:
        (suppressed if f.fingerprint in baseline else new).append(f)
    return new, suppressed
