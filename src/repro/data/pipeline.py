"""Deterministic synthetic data pipeline (shard-aware, checkpointable).

Tokens are a stateless hash of (seed, step, position) so that any host can
regenerate any shard of any step — restart/elastic-re-mesh safe by
construction (the pipeline "state" is just the step counter, stored in the
checkpoint's extra dict).  The generated stream has local n-gram structure
(a small LCG-mixed Markov walk) so cross-entropy is learnable — integration
tests assert the loss drops.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model_zoo
from repro.obs import instrument as obs


def _hash2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ b.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    return x


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ModelConfig
    rc: RunConfig
    seed: int = 0
    step: int = 0

    def state(self) -> Dict[str, int]:
        return {"data_step": self.step, "data_seed": self.seed}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = int(state.get("data_step", 0))
        self.seed = int(state.get("data_seed", self.seed))

    def _tokens(self, step: int, batch: int, seq: int) -> np.ndarray:
        """Markov-ish walk: next token mixes previous token and position hash."""
        v = self.cfg.vocab
        rows = np.arange(batch, dtype=np.uint64)[:, None]
        cols = np.arange(seq + 1, dtype=np.uint64)[None, :]
        base = _hash2(rows + np.uint64(step * 131071 + self.seed),
                      cols)
        # local structure: token depends mostly on coarse position bucket
        walk = (base >> np.uint64(8)) % np.uint64(max(v // 16, 2))
        drift = (cols // np.uint64(17)) % np.uint64(max(v // 16, 2))
        toks = (walk + drift * np.uint64(16)) % np.uint64(v)
        return toks.astype(np.int32)

    def next(self) -> Dict[str, Any]:
        if not obs.enabled():
            return self._next()
        t0 = time.perf_counter()
        batch = self._next()
        obs.hist_observe("data/batch_ms", (time.perf_counter() - t0) * 1e3,
                         arch=self.cfg.name)
        obs.counter_inc("data/batches", 1, arch=self.cfg.name)
        obs.counter_inc("data/bytes",
                        sum(np.asarray(v).nbytes for v in batch.values()),
                        arch=self.cfg.name)
        return batch

    def _next(self) -> Dict[str, Any]:
        cfg, rc = self.cfg, self.rc
        B, S = rc.global_batch, rc.seq_len
        if cfg.family == "vlm":
            S_text = S - cfg.n_vis_tokens
            toks = self._tokens(self.step, B, S_text)
            batch = {
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
                "vis_embeds": self._embeds(B, cfg.n_vis_tokens),
            }
        elif cfg.family == "encdec":
            toks = self._tokens(self.step, B, S)
            batch = {
                "frames": self._embeds(B, cfg.enc_seq),
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
            }
        else:
            toks = self._tokens(self.step, B, S)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        self.step += 1
        return batch

    def _embeds(self, batch: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7919 + self.step)
        x = rng.standard_normal((batch, n, self.cfg.d_model)) * 0.02
        return x.astype(np.float32)


def device_batch(batch: Dict[str, Any], cfg: ModelConfig, rc: RunConfig,
                 shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Cast to the cell's input dtypes and place on device(s)."""
    specs = model_zoo.input_specs(cfg, rc)
    out = {}
    for k, v in batch.items():
        spec = specs[k]
        arr = np.asarray(v)
        sh = shardings.get(k) if shardings else None
        out[k] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
        if out[k].dtype != spec.dtype:
            out[k] = out[k].astype(spec.dtype)
    return out
