"""Pallas TPU kernels: delta + bitplane pack / unpack (paper §2.5 + §2.4).

The FPGA compressor's loop-carried delta chain and bit-serial packing are
re-expressed for the TPU VPU (see DESIGN.md §2):

* the delta becomes a shifted lane-wise subtract,
* variable-length packing becomes a 32x32 bitplane transpose keeping only the
  ``bits`` low planes (shift/or network, fully vectorized),
* decode reconstructs with a log-depth lane prefix sum (the cumulative sum is
  the inverse of the delta chain).

Tiling: codes are processed in (BM, BLOCK) VMEM tiles, BLOCK a multiple of
32 lanes x groups; packed planes live in (BM, BLOCK//32*bits) tiles.  All
dims are multiples of (8, 128) for f32/i32 VMEM tile alignment when
BLOCK >= 128 and bits*BLOCK//32 >= 128 (asserted in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32
DEF_BM = 8  # sublane tile


def _delta_lanes(v: jax.Array) -> jax.Array:
    """v[:, k] - v[:, k-1] along lanes, first lane raw (int32, exact)."""
    shifted = jnp.pad(v, ((0, 0), (1, 0)))[:, :-1]
    return v - shifted


def _prefix_sum_lanes(v: jax.Array) -> jax.Array:
    """Log-depth inclusive prefix sum along the lane axis (int32, exact)."""
    n = v.shape[-1]
    k = 1
    while k < n:
        shifted = jnp.pad(v, ((0, 0), (k, 0)))[:, :-k]
        v = v + shifted
        k *= 2
    return v


def _pack_kernel(q_ref, out_ref, *, bits: int, block: int):
    v = q_ref[...]                                    # (BM, BLOCK) int32
    d = _delta_lanes(v).astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    d = d & mask
    bm = v.shape[0]
    g = d.reshape(bm, block // GROUP, GROUP)
    w = jnp.uint32(1) << jnp.arange(GROUP, dtype=jnp.uint32)
    planes = []
    for j in range(bits):                             # static unroll
        bit_j = (g >> jnp.uint32(j)) & jnp.uint32(1)
        planes.append(jnp.sum(bit_j * w, axis=-1, dtype=jnp.uint32))
    out = jnp.stack(planes, axis=-1)                  # (BM, G, bits)
    out_ref[...] = out.reshape(bm, -1).astype(jnp.uint32)


def _unpack_kernel(p_ref, out_ref, *, bits: int, block: int):
    planes = p_ref[...].astype(jnp.uint32)            # (BM, G*bits)
    bm = planes.shape[0]
    g = planes.reshape(bm, block // GROUP, bits)
    vals = jnp.zeros((bm, block // GROUP, GROUP), dtype=jnp.uint32)
    i = jnp.arange(GROUP, dtype=jnp.uint32)
    for j in range(bits):                             # static unroll
        bit_ij = (g[:, :, j][:, :, None] >> i) & jnp.uint32(1)
        vals = vals | (bit_ij << jnp.uint32(j))
    if bits < 32:
        h = jnp.uint32(1 << (bits - 1))
        vals = (vals ^ h) - h                         # sign extend
    d = vals.astype(jnp.int32).reshape(bm, block)
    out_ref[...] = _prefix_sum_lanes(d)


@functools.partial(jax.jit, static_argnames=("bits", "block", "bm", "interpret"))
def pack(q: jax.Array, *, bits: int, block: int, bm: int = DEF_BM,
         interpret: bool = False) -> jax.Array:
    """int32 codes [N, block] -> packed planes uint32 [N, block//32*bits]."""
    n = q.shape[0]
    assert q.shape == (n, block) and n % bm == 0, (q.shape, bm)
    pw = block // GROUP * bits
    return pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits, block=block),
        grid=(n // bm,),
        in_specs=[pl.BlockSpec((bm, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, pw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, pw), jnp.uint32),
        interpret=interpret,
    )(q)


@functools.partial(jax.jit, static_argnames=("bits", "block", "bm", "interpret"))
def unpack(planes: jax.Array, *, bits: int, block: int, bm: int = DEF_BM,
           interpret: bool = False) -> jax.Array:
    """Packed planes uint32 [N, block//32*bits] -> int32 codes [N, block]."""
    n = planes.shape[0]
    pw = block // GROUP * bits
    assert planes.shape == (n, pw) and n % bm == 0, (planes.shape, pw, bm)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits, block=block),
        grid=(n // bm,),
        in_specs=[pl.BlockSpec((bm, pw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, block), jnp.int32),
        interpret=interpret,
    )(planes)
