"""Pallas TPU kernel: chunked jacobi-1d with irredundant inter-tile carry.

The paper's §4 macro-pipeline (read MARS -> execute tile -> write MARS) maps
onto a sequential Pallas grid: each grid step DMAs one space chunk HBM->VMEM,
advances it ``T`` time steps, and writes the chunk's outputs back.  The
inter-tile dataflow — the MARS — is the 2 columns x T time-levels that each
chunk's left edge needs from its predecessor; it is carried through a VMEM
scratch buffer (the on-chip FIFO of Fig. 4/8) so it is never re-read from
HBM and never recomputed: the transfer is *irredundant*, exactly the paper's
property, where a conventional overlapped (trapezoidal) tiling would re-read
and recompute a T-wide halo per chunk.

Skewed chunk geometry: at time level s (0-based input = s=0), grid step c
holds values for cells [cW - s, (c+1)W - s).  Stepping needs two extra left
columns (from the carry) and reuses its own right edge.  Consequently output
block c of the result buffer holds cells [cW - T, (c+1)W - T) at time T; the
wrapper in ops.py shifts indices and handles the global boundary strip.

Boundary contract (matches kernels/ref.py::jacobi_chunked_ref): edge values
are replicated, i.e. cell 0 and n-1 see a clamped neighbourhood.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams (<=0.4.x) to CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(x_ref, y_ref, carry_ref, *, t_steps: int, width: int):
    c = pl.program_id(0)
    v = x_ref[...]                                    # (1, W) cells [cW,(c+1)W)

    @pl.when(c == 0)
    def _init_carry():
        # ghost region left of cell 0 = replicated edge value; jacobi of a
        # constant is constant, so the ghost stays x[0] at every time level.
        carry_ref[...] = jnp.full((t_steps, 2), v[0, 0], dtype=v.dtype)

    for s in range(1, t_steps + 1):
        left2 = carry_ref[s - 1, :].reshape(1, 2)     # cells [cW-s-1, cW-s+1)
        carry_ref[s - 1, :] = v[0, -2:]               # MARS out -> next chunk
        ext = jnp.concatenate([left2, v], axis=1)     # (1, W+2)
        v = (ext[:, :-2] + ext[:, 1:-1] + ext[:, 2:]) / 3.0

    y_ref[...] = v                                    # cells [cW-T,(c+1)W-T)


@functools.partial(jax.jit, static_argnames=("t_steps", "width", "interpret"))
def jacobi_chunked(x: jax.Array, *, t_steps: int, width: int = 512,
                   interpret: bool = False) -> jax.Array:
    """T jacobi steps over [n] f32; returns the *skewed* output buffer.

    y[c*W + k] = value of cell (c*W - T + k) at time T.  Use
    ops.jacobi1d_tiled for the user-facing unskewed version.
    """
    n = x.shape[0]
    assert n % width == 0, (n, width)
    assert t_steps < width - 2, "carry depth must fit one chunk"
    x2 = x.reshape(1, n).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, width=width),
        grid=(n // width,),
        in_specs=[pl.BlockSpec((1, width), lambda c: (0, c))],
        out_specs=pl.BlockSpec((1, width), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t_steps, 2), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x2)
    return out.reshape(n)
