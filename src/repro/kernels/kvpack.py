"""Pallas TPU kernels: packed KV-cache block quantization (DESIGN.md §3.2).

KV blocks (positions x head_dim) are the serving-side MARS: atomic (a decode
step reads whole blocks), irredundant (each block stored once), contiguous.
Packing them to int8/int4 with a per-row scale marker cuts the decode memory
roofline term 2-4x.  The scale array is the §4.2.2 metadata analogue.

Kernels:
  * quant:   f32/bf16 [rows, d] -> int8 codes [rows, d(, /2)] + f32 scales
  * dequant: inverse, used on the attention read path.

Tiling: (BM, d) VMEM tiles; d is the head_dim (128-aligned in all assigned
architectures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BM = 8


def _quant_kernel(x_ref, q_ref, s_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                  # (BM, D)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        q_ref[...] = q.astype(jnp.int8)
    else:  # int4: lo nibble = even column; paired reshape stays contiguous
        pairs = (q & 0xF).reshape(q.shape[0], -1, 2)
        q_ref[...] = (pairs[:, :, 0] | (pairs[:, :, 1] << 4)).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, bits: int):
    codes = q_ref[...].astype(jnp.int32)
    if bits == 8:
        q = codes
    else:
        def sext4(v):
            return ((v & 0xF) ^ 0x8) - 0x8
        lo = sext4(codes)
        hi = sext4(codes >> 4)
        q = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[0], -1)
    x_ref[...] = q.astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def kv_quant(x: jax.Array, *, bits: int = 8, bm: int = DEF_BM,
             interpret: bool = False):
    """[rows, d] float -> (codes int8, scales f32 [rows, 1])."""
    rows, d = x.shape
    assert rows % bm == 0 and (bits == 8 or d % 2 == 0)
    cd = d if bits == 8 else d // 2
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, cd), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cd), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def kv_dequant(codes: jax.Array, scales: jax.Array, *, bits: int = 8,
               bm: int = DEF_BM, interpret: bool = False) -> jax.Array:
    rows, cd = codes.shape
    d = cd if bits == 8 else cd * 2
    assert rows % bm == 0
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits),
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, cd), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=interpret,
    )(codes, scales)
