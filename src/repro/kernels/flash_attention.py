"""Pallas TPU flash attention (GQA, causal / sliding-window).

The roofline analysis (EXPERIMENTS.md §Roofline) shows the pure-XLA
blockwise attention spills its S^2-shaped intermediates to HBM — the
dominant memory term of every train/prefill cell.  This kernel keeps the
s/p blocks in VMEM (the paper's insight applied to attention: contiguous
blocks + on-chip reuse = bandwidth saved), reducing attention HBM traffic
to the q/k/v/o I/O.

Layout: q (B, S, KV, G, D); k/v (B, S, KV, D) — grouped GQA, no repeated
KV materialization.  Grid (B, KV, G, nq, nk): nk innermost, online-softmax
state (m, l, acc) carried in VMEM scratch across the nk sweep.

Forward + backward (dq, dk, dv) kernels with jax.custom_vjp; backward
recomputes p per block from the saved (m, l) — the flash-2 scheme.
Validated in interpret mode against kernels/ref.py and jax.grad of the
reference in tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams (<=0.4.x) to CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

F32 = jnp.float32
NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, bq: int, bk: int, nk: int, causal: bool, window: int,
                scale: float):
    qi, ki = pl.program_id(3), pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, 0, :].astype(F32)              # (bq, D)
    k = k_ref[0, :, 0, :].astype(F32)                 # (bk, D)
    v = v_ref[0, :, 0, :].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    s = jnp.where(_mask(q_pos, k_pos, causal, window), s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + p.sum(axis=1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, 0, :] = m_scr[...] + jnp.log(l)


def _flash_fwd(q, k, v, *, causal: bool, window: int, bq: int, bk: int,
               interpret: bool):
    B, S, KV, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = S // bq, Sk // bk
    scale = D ** -0.5
    grid = (B, KV, G, nq, nk)
    kernel = functools.partial(
        _fwd_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        scale=scale)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, g, qi, ki: (b, qi, h, g, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, g, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, g, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, g, qi, ki: (b, qi, h, g, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, g, qi, ki: (b, h, g, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, KV, G, S), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq, D), F32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward (flash-2: recompute p from lse; dkv sweep then dq sweep)
# ---------------------------------------------------------------------------

def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr,
                    *, bq: int, bk: int, nq: int, ng: int, causal: bool,
                    window: int, scale: float):
    # grid (B, KV, nk, G, nq): the (g, qi) sweep is sequential so dk/dv for a
    # kv block accumulate over every query group and q block in scratch
    ki, gi, qi = pl.program_id(2), pl.program_id(3), pl.program_id(4)

    @pl.when(jnp.logical_and(gi == 0, qi == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, :, 0, 0, :].astype(F32)
    k = k_ref[0, :, 0, :].astype(F32)
    v = v_ref[0, :, 0, :].astype(F32)
    do = do_ref[0, :, 0, 0, :].astype(F32)
    lse = lse_ref[0, 0, 0, :]
    delta = delta_ref[0, 0, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    mask = _mask(q_pos, k_pos, causal, window)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)      # (bq, bk)

    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(jnp.logical_and(gi == ng - 1, qi == nq - 1))
    def _finish():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_scr, *, bq: int, bk: int, nk: int, causal: bool,
                   window: int, scale: float):
    qi, ki = pl.program_id(3), pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, :, 0, 0, :].astype(F32)
    k = k_ref[0, :, 0, :].astype(F32)
    v = v_ref[0, :, 0, :].astype(F32)
    do = do_ref[0, :, 0, 0, :].astype(F32)
    lse = lse_ref[0, 0, 0, :]
    delta = delta_ref[0, 0, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    mask = _mask(q_pos, k_pos, causal, window)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, :, 0, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd(res, g, *, causal, window, bq, bk, interpret):
    q, k, v, o, lse = res
    do, _ = g
    B, S, KV, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = S // bq, Sk // bk
    scale = D ** -0.5
    delta = jnp.sum(o.astype(F32) * do.astype(F32), axis=-1)   # (B,S,KV,G)
    delta = jnp.transpose(delta, (0, 2, 3, 1))                 # (B,KV,G,S)

    dkv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq, ng=G,
                          causal=causal, window=window, scale=scale),
        grid=(B, KV, nk, G, nq),
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, ki, g, qi: (b, qi, h, g, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, g, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, g, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, ki, g, qi: (b, qi, h, g, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, ki, g, qi: (b, h, g, qi)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, ki, g, qi: (b, h, g, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, g, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, g, qi: (b, ki, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sk, KV, D), F32),
            jax.ShapeDtypeStruct((B, Sk, KV, D), F32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), F32), pltpu.VMEM((bk, D), F32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dkv[0]
    dv = dkv[1]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, nk=nk,
                          causal=causal, window=window, scale=scale),
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, g, qi, ki: (b, qi, h, g, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, g, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, g, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bq, 1, 1, D), lambda b, h, g, qi, ki: (b, qi, h, g, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, g, qi, ki: (b, h, g, qi)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, g, qi, ki: (b, h, g, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, 1, D),
                               lambda b, h, g, qi, ki: (b, qi, h, g, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), F32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q: (B,S,KV,G,D); k,v: (B,Sk,KV,D) -> o: (B,S,KV,G,D)."""
    o, _ = _flash_fwd(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                      interpret=interpret)
    return o


def _vjp_fwd(q, k, v, causal, window, bq, bk, interpret):
    o, lse = _flash_fwd(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                        interpret=interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, window, bq, bk, interpret, res, g):
    return _flash_bwd(res, (g, None), causal=causal, window=window, bq=bq,
                      bk=bk, interpret=interpret)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
