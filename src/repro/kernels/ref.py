"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-exact (or tolerance-specified) reference the kernels
are validated against in ``tests/test_kernels.py`` (interpret mode) and that
XLA falls back to where a kernel is not applicable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blockcodec as bc


# ---------------------------------------------------------------------------
# delta + bitplane pack / unpack (the paper's codec, TPU block form)
# ---------------------------------------------------------------------------

def pack_ref(q: jax.Array, bits: int) -> jax.Array:
    """int32 codes [N, block] -> packed planes uint32 [N, block//32 * bits].

    Delta along the minor axis (first element raw), truncate to ``bits``
    two's-complement bits, bitplane-transpose each 32-word group.
    """
    n, block = q.shape
    d = bc.delta_encode(q)
    g = d.reshape(n, block // bc.GROUP, bc.GROUP)
    planes = bc.bitplane_pack(g, bits)            # [N, G, bits]
    return planes.reshape(n, -1)


def unpack_ref(planes: jax.Array, bits: int, block: int) -> jax.Array:
    """Inverse of pack_ref -> int32 codes [N, block]."""
    n = planes.shape[0]
    g = planes.reshape(n, block // bc.GROUP, bits)
    d = bc.bitplane_unpack(g, bits).reshape(n, block)
    return bc.delta_decode(d)


# ---------------------------------------------------------------------------
# KV-cache block quantization (packed int8 / int4 + per-row scale markers)
# ---------------------------------------------------------------------------

def kv_quant_ref(x: jax.Array, bits: int = 8):
    """[rows, d] float -> (codes int8 [rows, d or d/2], scale f32 [rows, 1]).

    Symmetric per-row quantization; int4 packs two codes per byte
    (lo nibble = even column).
    """
    x = x.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        return q.astype(jnp.int8), scale
    if bits == 4:
        # contiguous nibble interleave: pair columns and weight-sum the
        # innermost axis (lo|hi == lo + 16*hi on disjoint nibbles) —
        # q[..., 0::2] strided slices lower to gathers, breaking bursts
        pairs = (q & 0xF).reshape(*q.shape[:-1], -1, 2)
        packed = pairs[..., 0] | (pairs[..., 1] << 4)
        return packed.astype(jnp.int8), scale
    raise ValueError(bits)


def kv_dequant_ref(codes: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    codes = codes.astype(jnp.int32)
    if bits == 8:
        q = codes
    elif bits == 4:
        def sext4(v):
            return ((v & 0xF) ^ 0x8) - 0x8
        lo = sext4(codes)
        hi = sext4(codes >> 4)
        q = jnp.stack([lo, hi], axis=-1).reshape(*codes.shape[:-1], -1)
    else:
        raise ValueError(bits)
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Chunked jacobi-1d (read -> execute x T -> write macro-pipeline)
# ---------------------------------------------------------------------------

def jacobi_chunked_ref(x: jax.Array, t_steps: int) -> jax.Array:
    """T jacobi steps on the edge-padded infinite extension of x.

    Contract shared with the Pallas kernel: the input is conceptually
    extended left and right with its edge values *at time 0*, then evolved
    T steps; the n interior cells are returned.  (Influence distance is
    exactly T cells, so padding by T is exact.)
    """
    v = jnp.pad(x.astype(jnp.float32), (t_steps, t_steps), mode="edge")
    for _ in range(t_steps):
        v = (v[:-2] + v[1:-1] + v[2:]) / 3.0   # 'valid' update, shrinks by 2
    return v
