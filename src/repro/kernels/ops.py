"""Public jit'd wrappers around the Pallas kernels (with jnp fallbacks).

``use_pallas`` controls the backend: "auto" picks Pallas on TPU and the pure
jnp oracle elsewhere (this CPU container validates kernels via
interpret=True in tests; production traffic on CPU hosts shouldn't pay the
interpreter cost).

Every entry point is a *host-side* wrapper around the jitted kernel call,
so it can publish per-kernel ``repro.obs`` series without recording inside
a trace (the PR-6 rule): ``kernels/hbm_bytes{kernel=,dir=}`` and
``kernels/beats{kernel=,dir=}`` are computed analytically from the operand
shapes (what a roofline model charges the kernel: read every input once,
write every output once), ``kernels/calls`` counts invocations, and a
``kernels/<name>`` span brackets the dispatch.  When an entry point is
reached *inside* someone else's trace (operands are tracers), recording is
skipped entirely — trace-time counters would fire once per compile, not
once per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs import instrument as obs

from . import bitplane, jacobi_mars, kvpack, ref

#: analytic HBM transaction beat, bytes (256-bit bus word) — the logical
#: unit ``kernels/beats`` counts; deterministic, not a measured quantity
BEAT_BYTES = 32


# ---------------------------------------------------------------------------
# Analytic I/O models (read every input once, write every output once) —
# shared by the ``_record`` instrumentation below and by
# ``repro.launch.audit``, which cross-checks them against the entry
# parameter/result bytes of the compiled HLO.
# ---------------------------------------------------------------------------

def pack_io_bytes(n: int, block: int, bits: int):
    """(read, write) bytes for pack_codes: s32 codes -> u32 bitplanes."""
    return n * block * 4, n * (block // 32 * bits) * 4


def unpack_io_bytes(n: int, block: int, bits: int):
    """(read, write) bytes for unpack_codes (pack's mirror)."""
    w, r = pack_io_bytes(n, block, bits)
    return r, w


def kv_quant_io_bytes(rows: int, d: int, bits: int, itemsize: int = 4):
    """(read, write) bytes for kv_quant: x -> (packed codes, f32 scales)."""
    cd = d if bits == 8 else d // 2
    return rows * d * itemsize, rows * cd + rows * 4


def kv_dequant_io_bytes(rows: int, d: int, bits: int):
    """(read, write) bytes for kv_dequant: (codes, scales) -> f32 values."""
    r, w = kv_quant_io_bytes(rows, d, bits)
    return w, rows * d * 4


def jacobi_io_bytes(n: int):
    """(read, write) bytes for jacobi1d: each f32 cell read/written once."""
    return n * 4, n * 4


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(use_pallas: str | bool) -> str:
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    if use_pallas in (True, "pallas"):
        return "pallas"
    if use_pallas in ("interpret",):
        return "interpret"
    return "ref"


def _traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def _record(kernel: str, mode: str, read_bytes: int, write_bytes: int,
            **labels) -> None:
    """Publish the analytic traffic of one kernel dispatch (host side)."""
    if not obs.enabled():
        return
    obs.counter_inc("kernels/calls", 1, kernel=kernel, mode=mode, **labels)
    for d, nbytes in (("read", read_bytes), ("write", write_bytes)):
        obs.counter_inc("kernels/hbm_bytes", int(nbytes), kernel=kernel,
                        mode=mode, dir=d, **labels)
        obs.counter_inc("kernels/beats", -(-int(nbytes) // BEAT_BYTES),
                        kernel=kernel, mode=mode, dir=d, **labels)


# ---------------------------------------------------------------------------
# delta+bitplane codec
# ---------------------------------------------------------------------------

def pack_codes(q: jax.Array, bits: int, use_pallas: str | bool = "auto") -> jax.Array:
    """int32 codes [N, block] -> uint32 planes [N, block//32*bits]."""
    n, block = q.shape
    m = _mode(use_pallas)
    record = not _traced(q)
    with obs.span("kernels/pack", mode=m, bits=bits):
        if m == "ref":
            out = ref.pack_ref(q, bits)
        else:
            out = bitplane.pack(q, bits=bits, block=block,
                                interpret=(m == "interpret"))
    if record:
        _record("pack", m, *pack_io_bytes(n, block, bits), bits=bits)
    return out


def unpack_codes(planes: jax.Array, bits: int, block: int,
                 use_pallas: str | bool = "auto") -> jax.Array:
    m = _mode(use_pallas)
    record = not _traced(planes)
    with obs.span("kernels/unpack", mode=m, bits=bits):
        if m == "ref":
            out = ref.unpack_ref(planes, bits, block)
        else:
            out = bitplane.unpack(planes, bits=bits, block=block,
                                  interpret=(m == "interpret"))
    if record:
        n = planes.shape[0]
        _record("unpack", m, *unpack_io_bytes(n, block, bits), bits=bits)
    return out


# ---------------------------------------------------------------------------
# KV block packing
# ---------------------------------------------------------------------------

def kv_quant(x: jax.Array, bits: int = 8, use_pallas: str | bool = "auto"):
    m = _mode(use_pallas)
    record = not _traced(x)
    with obs.span("kernels/kv_quant", mode=m, bits=bits):
        if m == "ref":
            out = ref.kv_quant_ref(x, bits)
        else:
            out = kvpack.kv_quant(x, bits=bits, interpret=(m == "interpret"))
    if record:
        rows, d = x.shape
        _record("kv_quant", m,
                *kv_quant_io_bytes(rows, d, bits, x.dtype.itemsize),
                bits=bits)
    return out


def kv_dequant(codes: jax.Array, scales: jax.Array, bits: int = 8,
               use_pallas: str | bool = "auto") -> jax.Array:
    m = _mode(use_pallas)
    record = not _traced(codes, scales)
    with obs.span("kernels/kv_dequant", mode=m, bits=bits):
        if m == "ref":
            out = ref.kv_dequant_ref(codes, scales, bits)
        else:
            out = kvpack.kv_dequant(codes, scales, bits=bits,
                                    interpret=(m == "interpret"))
    if record:
        _record("kv_dequant", m,
                *kv_dequant_io_bytes(codes.shape[0], out.shape[-1], bits),
                bits=bits)
    return out


# ---------------------------------------------------------------------------
# Chunked jacobi (stencil macro-pipeline demo)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("t_steps", "width", "use_pallas"))
def _jacobi1d_tiled_jit(x: jax.Array, t_steps: int, width: int,
                        use_pallas: str | bool) -> jax.Array:
    m = _mode(use_pallas)
    if m == "ref":
        return ref.jacobi_chunked_ref(x, t_steps)
    n = x.shape[0]
    assert t_steps < width - 2, (t_steps, width)
    pad_right = (-(n + width + t_steps)) % width + t_steps
    xp = jnp.concatenate([
        jnp.full((width,), x[0], dtype=jnp.float32),
        x.astype(jnp.float32),
        jnp.full((pad_right,), x[-1], dtype=jnp.float32),
    ])
    ybuf = jacobi_mars.jacobi_chunked(xp, t_steps=t_steps, width=width,
                                      interpret=(m == "interpret"))
    return jax.lax.dynamic_slice(ybuf, (width + t_steps,), (n,))


def jacobi1d_tiled(x: jax.Array, t_steps: int, width: int = 512,
                   use_pallas: str | bool = "auto") -> jax.Array:
    """T jacobi steps (edge-padded open-boundary contract), chunked execution.

    The kernel runs over a padded domain: one full ghost chunk of x[0] on the
    left (so the first real chunk's carry is exact — the frozen far-left
    carry sits > width-T cells from any real cell) and edge padding on the
    right (the paper's 'partial tiles on host' become constant ghost regions
    here).  Kernel output block c holds cells [cW - T, (c+1)W - T) of the
    padded domain; real cell m lives at ybuf[m + width + T].

    HBM accounting charges the irredundant scheme: each cell is read once
    and written once per pass regardless of T, the carry riding in VMEM
    scratch (vs overlapped tiling's T-wide halo re-reads — see
    benchmarks/bench_stencil_kernel.py for the comparison model).
    """
    m = _mode(use_pallas)
    record = not _traced(x)
    with obs.span("kernels/jacobi1d", mode=m, t_steps=t_steps, width=width):
        out = _jacobi1d_tiled_jit(x, t_steps, width, use_pallas)
    if record:
        n = x.shape[0]
        _record("jacobi1d", m, *jacobi_io_bytes(n), t_steps=t_steps)
    return out
