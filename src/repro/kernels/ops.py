"""Public jit'd wrappers around the Pallas kernels (with jnp fallbacks).

``use_pallas`` controls the backend: "auto" picks Pallas on TPU and the pure
jnp oracle elsewhere (this CPU container validates kernels via
interpret=True in tests; production traffic on CPU hosts shouldn't pay the
interpreter cost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitplane, jacobi_mars, kvpack, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(use_pallas: str | bool) -> str:
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    if use_pallas in (True, "pallas"):
        return "pallas"
    if use_pallas in ("interpret",):
        return "interpret"
    return "ref"


# ---------------------------------------------------------------------------
# delta+bitplane codec
# ---------------------------------------------------------------------------

def pack_codes(q: jax.Array, bits: int, use_pallas: str | bool = "auto") -> jax.Array:
    """int32 codes [N, block] -> uint32 planes [N, block//32*bits]."""
    n, block = q.shape
    m = _mode(use_pallas)
    if m == "ref":
        return ref.pack_ref(q, bits)
    return bitplane.pack(q, bits=bits, block=block, interpret=(m == "interpret"))


def unpack_codes(planes: jax.Array, bits: int, block: int,
                 use_pallas: str | bool = "auto") -> jax.Array:
    m = _mode(use_pallas)
    if m == "ref":
        return ref.unpack_ref(planes, bits, block)
    return bitplane.unpack(planes, bits=bits, block=block,
                           interpret=(m == "interpret"))


# ---------------------------------------------------------------------------
# KV block packing
# ---------------------------------------------------------------------------

def kv_quant(x: jax.Array, bits: int = 8, use_pallas: str | bool = "auto"):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.kv_quant_ref(x, bits)
    return kvpack.kv_quant(x, bits=bits, interpret=(m == "interpret"))


def kv_dequant(codes: jax.Array, scales: jax.Array, bits: int = 8,
               use_pallas: str | bool = "auto") -> jax.Array:
    m = _mode(use_pallas)
    if m == "ref":
        return ref.kv_dequant_ref(codes, scales, bits)
    return kvpack.kv_dequant(codes, scales, bits=bits,
                             interpret=(m == "interpret"))


# ---------------------------------------------------------------------------
# Chunked jacobi (stencil macro-pipeline demo)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("t_steps", "width", "use_pallas"))
def jacobi1d_tiled(x: jax.Array, t_steps: int, width: int = 512,
                   use_pallas: str | bool = "auto") -> jax.Array:
    """T jacobi steps (edge-padded open-boundary contract), chunked execution.

    The kernel runs over a padded domain: one full ghost chunk of x[0] on the
    left (so the first real chunk's carry is exact — the frozen far-left
    carry sits > width-T cells from any real cell) and edge padding on the
    right (the paper's 'partial tiles on host' become constant ghost regions
    here).  Kernel output block c holds cells [cW - T, (c+1)W - T) of the
    padded domain; real cell m lives at ybuf[m + width + T].
    """
    m = _mode(use_pallas)
    if m == "ref":
        return ref.jacobi_chunked_ref(x, t_steps)
    n = x.shape[0]
    assert t_steps < width - 2, (t_steps, width)
    pad_right = (-(n + width + t_steps)) % width + t_steps
    xp = jnp.concatenate([
        jnp.full((width,), x[0], dtype=jnp.float32),
        x.astype(jnp.float32),
        jnp.full((pad_right,), x[-1], dtype=jnp.float32),
    ])
    ybuf = jacobi_mars.jacobi_chunked(xp, t_steps=t_steps, width=width,
                                      interpret=(m == "interpret"))
    return jax.lax.dynamic_slice(ybuf, (width + t_steps,), (n,))
