"""Train-step factory: loss -> grads -> (optionally compressed) exchange -> AdamW.

Two gradient paths:

* baseline: ``jax.value_and_grad`` under jit — GSPMD inserts the gradient
  reduce-scatter/all-reduce over ('pod','data') automatically;
* compressed (``rc.grad_compress_bits > 0`` on a multi-pod mesh): the
  fwd+bwd is vmapped over a pod-sharded leading batch axis so each pod
  produces pod-local grads (GSPMD active over 'data'/'model' exactly as in
  the plain path), then the paper-codec exchange in
  distributed/collectives.py crosses the pod boundary at ~bits/32 of the
  f32 volume, with error feedback carried in ``TrainState.resid``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import collectives, sharding as shd
from repro.models.model_zoo import ModelApi
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamState
    resid: Optional[Any]     # error-feedback residuals (leading pod dim) or None
    step: jax.Array


def adam_config(rc: RunConfig, total_steps: int = 10_000) -> adamw.AdamConfig:
    return adamw.AdamConfig(lr=rc.lr, weight_decay=rc.weight_decay,
                            grad_clip=rc.grad_clip, dtype=rc.opt_dtype,
                            total_steps=total_steps)


def _n_pods(mesh) -> int:
    return mesh.shape["pod"] if (mesh is not None and "pod" in mesh.axis_names) else 1


def init_state(api: ModelApi, rc: RunConfig, key, mesh=None) -> TrainState:
    params = api.init(key)
    opt = adamw.init(params, adam_config(rc))
    resid = None
    if rc.grad_compress_bits and _n_pods(mesh) > 1:
        n = _n_pods(mesh)
        resid = jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt, resid=resid,
                      step=jnp.zeros((), jnp.int32))


def abstract_state(api: ModelApi, rc: RunConfig, mesh=None) -> TrainState:
    return jax.eval_shape(
        lambda: init_state(api, rc, jax.random.PRNGKey(0), mesh))


def state_logical_specs(api: ModelApi, rc: RunConfig, mesh=None) -> TrainState:
    """Logical axis names for the whole TrainState."""
    pspecs = api.param_specs()
    resid = None
    if rc.grad_compress_bits and _n_pods(mesh) > 1:
        # residuals are pod-local: leading pod dim, then the param's own spec
        # (resolved minus the manual pod axis, see Rules.exclude)
        resid = jax.tree.map(lambda t: ("pod_dim",) + t, pspecs,
                             is_leaf=shd._is_logical_leaf)
    return TrainState(
        params=pspecs,
        opt=adamw.AdamState(mu=pspecs, nu=pspecs, count=()),
        resid=resid,
        step=(),
    )


def resolve_state_specs(logical: TrainState, abstract: TrainState) -> TrainState:
    """Resolve logical specs to PartitionSpecs ('pod_dim' -> 'pod' literally)."""
    r = shd.get_rules()

    def one(log, shp):
        if r is None:
            return P()
        if log and log[0] == "pod_dim":
            inner = r.spec(shp.shape[1:], log[1:])
            return P("pod", *inner)
        return r.spec(shp.shape, log)

    return jax.tree.map(one, logical, abstract, is_leaf=shd._is_logical_leaf)


def make_train_step(api: ModelApi, cfg: ModelConfig, rc: RunConfig, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    acfg = adam_config(rc)
    compress = bool(rc.grad_compress_bits) and _n_pods(mesh) > 1

    def plain_grads(params, batch):
        return jax.value_and_grad(api.loss_fn)(params, batch)

    if compress:
        bits = rc.grad_compress_bits
        n_pods = mesh.shape["pod"]
        # static split of gradient leaves: compressible vs raw (tiny)
        abs_params = jax.eval_shape(
            lambda: api.init(jax.random.PRNGKey(0)))
        flat_abs, treedef = jax.tree.flatten(abs_params)
        comp_mask = [collectives.compressible(a) for a in flat_abs]

        def per_pod_grads(params, batch_p):
            """(pods, B/pods, ...) batch -> per-pod (losses, grads).

            Pure auto-GSPMD: a ``shard_map`` manual over 'pod' would be the
            direct spelling, but this XLA's SPMD partitioner aborts (Check
            failed: IsManualSubgroup()) on any ``while`` op — scan-over-
            layers, attention block scans — inside a manual subgroup, so
            the per-pod fwd+bwd is a vmap over the pod-sharded leading axis
            instead.  'data'/'model' shard inside exactly as in the plain
            path; 'pod' is excluded from rule resolution because the
            mapped-away pod dim is invisible to the activation specs.
            """
            rules = shd.get_rules()

            def one(b):
                with shd.use_rules(dataclasses.replace(
                        rules, exclude=frozenset({"pod"}))
                        if rules is not None else None):
                    return jax.value_and_grad(api.loss_fn)(params, b)

            return jax.vmap(one)(batch_p)

    def train_step(state: TrainState, batch):
        if compress:
            pod_ns = shd.named_sharding(P("pod"))
            constrain = (lambda x: jax.lax.with_sharding_constraint(x, pod_ns)
                         if pod_ns is not None else x)
            batch_p = jax.tree.map(
                lambda a: a.reshape((n_pods, a.shape[0] // n_pods)
                                    + a.shape[1:]), batch)
            batch_p = jax.tree.map(constrain, batch_p)
            losses, grads_p = per_pod_grads(state.params, batch_p)
            # auto-GSPMD cross-pod exchange: quantize pod-locally (leading
            # pod dim pinned to the 'pod' axis), then static per-pod slices
            # of the packed planes — SPMD inserts the (compressed) pod
            # gathers; raw-fallback leaves cross the pod boundary verbatim
            flat_g = jax.tree.flatten(grads_p)[0]
            flat_r = jax.tree.flatten(state.resid)[0]
            flat_p = jax.tree.flatten(state.params)[0]
            flat_mean, new_resid_l = [], []
            for g, r, pref, is_c in zip(flat_g, flat_r, flat_p, comp_mask):
                if not is_c:
                    flat_mean.append(jnp.mean(g.astype(jnp.float32), axis=0)
                                     .astype(pref.dtype))
                    new_resid_l.append(jnp.zeros_like(r))
                    continue
                x = constrain(g.astype(jnp.float32) + r)
                planes, scales = collectives._quant_lastdim(x, bits)
                planes, scales = constrain(planes), constrain(scales)
                new_resid_l.append(
                    x - collectives._dequant_lastdim(planes, scales, bits,
                                                     x.shape))
                total = None
                for i in range(n_pods):
                    d = collectives._dequant_lastdim(
                        planes[i], scales[i], bits, pref.shape)
                    total = d if total is None else total + d
                flat_mean.append((total / n_pods).astype(pref.dtype))
            loss = jnp.mean(losses)
            grads = jax.tree.unflatten(treedef, flat_mean)
            new_resid = jax.tree.unflatten(treedef, new_resid_l)
        else:
            loss, grads = plain_grads(state.params, batch)
            new_resid = state.resid
        params, opt = adamw.update(grads, state.opt, state.params, acfg)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": adamw.global_norm(grads)}
        return TrainState(params=params, opt=opt, resid=new_resid,
                          step=state.step + 1), metrics

    return train_step
