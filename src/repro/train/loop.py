"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler watchdog, elastic re-mesh on restore.

The loop is deliberately structured the way a 1000-node fleet driver would
be:

* every step runs under a deadline watchdog — a straggling step (here:
  simulated) is logged and counted; on a real fleet the same hook triggers
  re-dispatch of the slow host's shard;
* any exception inside a step (injected in tests via ``failure_hook``)
  rolls back to the latest checkpoint and resumes — the data pipeline step
  counter restores from the checkpoint's extra dict so the batch sequence is
  bit-identical;
* restore goes through NamedShardings of the *current* mesh, so a run can
  resume on a different device count (elastic re-mesh) — exercised in
  tests/test_train_loop.py with different host-device meshes.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import SyntheticPipeline, device_batch
from repro.distributed import sharding as shd
from repro.models import model_zoo
from repro.obs import instrument as obs
from repro.train import step as train_step_mod

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    step_deadline_s: float = 120.0
    max_restarts: int = 3


def _state_shardings(api, rc, mesh, abstract):
    logical = train_step_mod.state_logical_specs(api, rc, mesh)
    specs = train_step_mod.resolve_state_specs(logical, abstract)
    if mesh is None:
        return None
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), specs)


def train(cfg: ModelConfig, rc: RunConfig, loop: LoopConfig,
          mesh=None, failure_hook: Optional[Callable[[int], None]] = None,
          log_every: int = 10) -> Dict[str, list]:
    """Run the loop; returns metric history."""
    rules = shd.Rules(mesh=mesh, seq_shard=rc.seq_shard, fsdp=rc.fsdp,
                      shard_vocab=rc.shard_vocab)
    with shd.use_rules(rules):
        api = model_zoo.get_api(cfg, rc)
        mgr = CheckpointManager(loop.ckpt_dir, keep=loop.keep)
        pipeline = SyntheticPipeline(cfg, rc)
        step_fn = train_step_mod.make_train_step(api, cfg, rc, mesh)
        abstract = train_step_mod.abstract_state(api, rc, mesh)
        shardings = _state_shardings(api, rc, mesh, abstract)
        jit_step = jax.jit(step_fn,
                           in_shardings=(shardings, None) if shardings else None,
                           out_shardings=(shardings, None) if shardings else None,
                           donate_argnums=(0,))

        def fresh_state():
            return train_step_mod.init_state(
                api, rc, jax.random.PRNGKey(0), mesh)

        def restore_latest():
            step_num = mgr.latest_step()
            if step_num is None:
                return fresh_state()
            flat_sh = jax.tree.leaves(shardings) if shardings else None
            state, extra = mgr.restore(
                step_num, abstract,
                sharding_fn=(lambda i, ref: flat_sh[i]) if flat_sh else None)
            pipeline.restore(extra)
            log.info("restored checkpoint at step %d", step_num)
            return state

        state = restore_latest()
        history: Dict[str, list] = {"loss": [], "step_time": [], "stragglers": 0,
                                    "restarts": 0}
        restarts = 0
        while int(jax.device_get(state.step)) < loop.total_steps:
            step_num = int(jax.device_get(state.step))
            try:
                if failure_hook is not None:
                    failure_hook(step_num)
                batch_np = pipeline.next()
                batch = device_batch(batch_np, cfg, rc)
                t0 = time.monotonic()
                # span wraps the traced call from outside (obs records
                # nothing inside jit-compiled code — see repro.obs)
                with obs.span("train/step", step=step_num, arch=cfg.name):
                    state, metrics = jit_step(state, batch)
                    loss = float(jax.device_get(metrics["loss"]))
                dt = time.monotonic() - t0
                obs.hist_observe("train/step_ms", dt * 1e3, arch=cfg.name)
                obs.gauge_set("train/loss", loss, arch=cfg.name)
                obs.counter_inc("train/steps", 1, arch=cfg.name)
                obs.counter_inc("train/tokens",
                                int(np.prod(batch_np["tokens"].shape))
                                if "tokens" in batch_np else 0, arch=cfg.name)
                if dt > loop.step_deadline_s:
                    history["stragglers"] += 1
                    obs.counter_inc("train/stragglers", 1, arch=cfg.name)
                    log.warning("step %d exceeded deadline (%.1fs) — "
                                "straggler mitigation would re-dispatch",
                                step_num, dt)
                history["loss"].append(loss)
                history["step_time"].append(dt)
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step_num}")
                if log_every and step_num % log_every == 0:
                    log.info("step %d loss %.4f (%.2fs)", step_num, loss, dt)
                if (step_num + 1) % loop.ckpt_every == 0:
                    mgr.save(step_num + 1, state, extra=pipeline.state())
            except (FloatingPointError, RuntimeError, ValueError) as e:
                restarts += 1
                history["restarts"] = restarts
                log.error("step %d failed (%s); restart %d/%d", step_num, e,
                          restarts, loop.max_restarts)
                if restarts > loop.max_restarts:
                    raise
                state = restore_latest()
        mgr.save(int(jax.device_get(state.step)), state,
                 extra=pipeline.state())
        mgr.wait()
        return history
