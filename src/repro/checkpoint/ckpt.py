"""Fault-tolerant checkpointing: atomic, keep-k, async, reshard-on-load.

Layout:  <dir>/step_<n>/   (written to step_<n>.tmp then os.replace'd)
             manifest.json   tree structure, shapes, dtypes, metadata
             leaf_<i>.npy    one array per pytree leaf

Arrays are written via ``jax.device_get`` (gathering shards); on load they
are ``device_put`` against the *current* mesh's NamedShardings — so a
checkpoint written on one mesh restores onto any other (elastic re-mesh /
reshard-on-load).  On a real fleet the .npy writes would go per-host via
ocp-style per-shard IO; the layout and protocol here are host-count agnostic
(manifest + leaves), single-process in this container.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import os
import shutil
import time
from typing import Any, Callable, List, Optional

import jax
import ml_dtypes
import numpy as np

from repro.obs import instrument as obs

#: numpy can't serialize low-precision float dtypes; store raw-int views
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _n_shards(leaf) -> int:
    """Addressable shards backing a leaf (1 for host arrays/scalars)."""
    try:
        return len(leaf.addressable_shards)
    except (AttributeError, TypeError):
        return 1


def _tree_paths(tree) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        self.wait()
        leaves = jax.tree.leaves(tree)
        if obs.enabled():
            # shard accounting happens here, before device_get gathers
            obs.counter_inc("ckpt/shards",
                            sum(_n_shards(l) for l in leaves), op="save")
        host_leaves = jax.device_get(leaves)    # gather before async write
        paths = _tree_paths(tree)
        if self.async_save:
            self._pending = self._pool.submit(
                self._write, step, host_leaves, paths, extra or {})
        else:
            self._write(step, host_leaves, paths, extra or {})

    def _write(self, step: int, leaves, paths, extra: dict) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(os.path.join(final, "manifest.json")):
            obs.counter_inc("ckpt/save_skipped", 1)
            return  # this step is already durably published
        t0 = time.perf_counter()
        nbytes = 0
        with obs.span("ckpt/save", step=step):
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra, "leaves": []}
            for i, (leaf, path) in enumerate(zip(leaves, paths)):
                arr = np.asarray(leaf)
                storable, dtype_name = _to_storable(arr)
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), storable)
                nbytes += storable.nbytes
                manifest["leaves"].append(
                    {"path": path, "shape": list(arr.shape),
                     "dtype": dtype_name})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final)              # atomic publish
            self._gc()
        obs.hist_observe("ckpt/save_ms", (time.perf_counter() - t0) * 1e3)
        obs.counter_inc("ckpt/saves", 1)
        obs.counter_inc("ckpt/bytes_written", nbytes)
        obs.counter_inc("ckpt/leaves", len(leaves), op="save")

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                sharding_fn: Optional[Callable] = None) -> tuple:
        """Restore into the structure of ``like``; reshard via sharding_fn.

        sharding_fn(leaf_index, abstract_leaf) -> Sharding | None.
        Returns (tree, extra dict).
        """
        t0 = time.perf_counter()
        nbytes = 0
        with obs.span("ckpt/restore", step=step):
            d = os.path.join(self.directory, f"step_{step:08d}")
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            flat, treedef = jax.tree.flatten(like)
            assert len(flat) == len(manifest["leaves"]), (
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"expected {len(flat)}")
            out = []
            for i, ref in enumerate(flat):
                want = manifest["leaves"][i]
                arr = _from_storable(
                    np.load(os.path.join(d, f"leaf_{i}.npy")), want["dtype"])
                assert list(arr.shape) == want["shape"]
                nbytes += arr.nbytes
                sh = sharding_fn(i, ref) if sharding_fn else None
                out.append(jax.device_put(arr, sh) if sh is not None
                           else jax.device_put(arr))
            tree = jax.tree.unflatten(treedef, out)
        obs.hist_observe("ckpt/restore_ms", (time.perf_counter() - t0) * 1e3)
        obs.counter_inc("ckpt/restores", 1)
        obs.counter_inc("ckpt/bytes_read", nbytes)
        obs.counter_inc("ckpt/leaves", len(flat), op="restore")
        if obs.enabled():
            obs.counter_inc("ckpt/shards",
                            sum(_n_shards(l) for l in out), op="restore")
        return tree, manifest["extra"]
