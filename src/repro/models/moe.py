"""Top-k MoE block (mixtral / grok): scatter-based token dispatch.

Static-shape dropping implementation (GShard/Switch lineage): each expert has
capacity C = ceil(topk * tokens * capacity_factor / E); tokens route to their
top-k experts, position-in-expert comes from a cumulative one-hot count, and
overflow tokens are dropped (scatter mode='drop').  The dispatch buffers
(E, C, d) are the MoE analogue of MARS blocks: atomic (an expert consumes its
buffer wholly), irredundant (each routed token copy stored once), contiguous.

Baseline sharding is TP-within-expert (ff dim on 'model'); an
expert-parallel mesh layout is explored in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd

F32 = jnp.float32


class MoeParams(NamedTuple):
    router: jax.Array      # (d, E)
    w_gate: jax.Array      # (E, d, ff)
    w_up: jax.Array        # (E, d, ff)
    w_down: jax.Array      # (E, ff, d)


def init_moe(key, cfg: ModelConfig, dtype) -> MoeParams:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return MoeParams(
        router=(jax.random.normal(k1, (d, E)) * s).astype(dtype),
        w_gate=(jax.random.normal(k2, (E, d, ff)) * s).astype(dtype),
        w_up=(jax.random.normal(k3, (E, d, ff)) * s).astype(dtype),
        w_down=(jax.random.normal(k4, (E, ff, d)) * ff ** -0.5).astype(dtype),
    )


def moe_specs() -> MoeParams:
    return MoeParams(
        router=("fsdp", None),
        w_gate=("experts", "fsdp", "ff"),
        w_up=("experts", "fsdp", "ff"),
        w_down=("experts", "ff", "fsdp"),
    )


def moe_block(x: jax.Array, p: MoeParams, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), load-balance aux loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.topk
    n = B * S
    xf = x.reshape(n, d)

    gate_logits = (xf @ p.router).astype(F32)             # (n, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (n, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch eq. 4/5)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=F32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    cap = int(cfg.capacity_factor * k * n / E)
    cap = max(cap, 1)

    e_flat = top_e.reshape(-1)                            # (n*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - onehot, e_flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                     # cap -> dropped

    tok_idx = jnp.repeat(jnp.arange(n), k)
    x_dup = jnp.take(xf, tok_idx, axis=0)                 # (n*k, d)
    idx = jnp.stack([e_flat, pos_c], axis=1)              # (n*k, 2)
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[idx[:, 0], idx[:, 1]].add(
        x_dup, mode="drop")                               # (E, C, d)
    buf = shd.act(buf, "experts", "batch", None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p.w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p.w_up)
    h = shd.act(h, "experts", "batch", "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p.w_down)     # (E, C, d)

    gathered = out_buf.at[idx[:, 0], idx[:, 1]].get(
        mode="fill", fill_value=0)                        # (n*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = top_w.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[tok_idx].add(gathered * w)
    return out.reshape(B, S, d), aux
