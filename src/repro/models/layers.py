"""Shared transformer layers: norms, RoPE, blockwise GQA attention, MLPs.

Design points (see DESIGN.md §6):
* pure functions over param pytrees; params are created by ``init`` fns and
  described by matching *logical sharding* trees (distributed/sharding.py);
* attention is blockwise (flash-style online softmax in pure JAX): memory per
  step is O(Bq x Bk), required for the 32k/500k shapes;
* RoPE uses the interleaved (GPT-J) pairing so head_dim stays shardable;
* GQA is computed in grouped form (B, S, KV, G, D) — no materialized repeat;
* sliding-window attention slices a static-width band per q block, so SWA
  FLOPs scale with S*W, not S^2 (what makes long_500k viable for mixtral
  and hymba).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


# ---------------------------------------------------------------------------
# RoPE (interleaved pairing)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, ..., D) with pairs (2i, 2i+1); pos: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=F32) / half)
    ang = pos.astype(F32)[..., None] * freqs              # (B, S, half)
    # broadcast over intermediate dims (heads etc.)
    extra = x.ndim - 3
    ang = ang.reshape(ang.shape[0], ang.shape[1], *([1] * extra), half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(F32).reshape(*x.shape[:-1], half, 2)
    x0, x1 = xf[..., 0], xf[..., 1]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    return jnp.stack([r0, r1], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention parameters
# ---------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jax.Array            # (d, H*hd)
    wk: jax.Array            # (d, KV*hd)
    wv: jax.Array            # (d, KV*hd)
    wo: jax.Array            # (H*hd, d)
    bq: Optional[jax.Array]  # (H*hd,) or None
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


def init_attn(key, cfg: ModelConfig, dtype) -> AttnParams:
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return AttnParams(
        wq=(jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
        wk=(jax.random.normal(k2, (d, KV * hd)) * s).astype(dtype),
        wv=(jax.random.normal(k3, (d, KV * hd)) * s).astype(dtype),
        wo=(jax.random.normal(k4, (H * hd, d)) * (H * hd) ** -0.5).astype(dtype),
        bq=jnp.zeros((H * hd,), dtype) if cfg.qkv_bias else None,
        bk=jnp.zeros((KV * hd,), dtype) if cfg.qkv_bias else None,
        bv=jnp.zeros((KV * hd,), dtype) if cfg.qkv_bias else None,
    )


def attn_specs(cfg: ModelConfig) -> AttnParams:
    b = ("heads",) if cfg.qkv_bias else None
    return AttnParams(
        wq=("fsdp", "heads"), wk=("fsdp", "heads"), wv=("fsdp", "heads"),
        wo=("heads", "fsdp"),
        bq=b, bk=b, bv=b,
    )


# ---------------------------------------------------------------------------
# Blockwise attention (training / prefill)
# ---------------------------------------------------------------------------

def _qkv(x: jax.Array, p: AttnParams, cfg: ModelConfig, pos: jax.Array):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p.wq
    k = x @ p.wk
    v = x @ p.wv
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _grouped(q: jax.Array, KV: int):
    """(B, S, H, D) -> (B, S, KV, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, KV, H // KV, D)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool, window: int,
                        q_block: int, kv_block: int) -> jax.Array:
    """Flash-style attention.  q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D).

    Full-causal mode scans all kv blocks per q block with masking (the upper
    triangle is computed-and-masked: a known 2x FLOP envelope, recorded in
    the roofline notes).  Sliding-window mode slices a static (window +
    q_block)-wide band per q block, giving S*W scaling.
    """
    B, S, H, D = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = D ** -0.5
    nq = S // q_block
    assert S % q_block == 0 and Sk % kv_block == 0, (S, Sk, q_block, kv_block)
    qg = _grouped(q, KV)                                   # (B,S,KV,G,D)

    def one_q_block(qi):
        qs = jax.lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, axis=1)
        q_pos = qi * q_block + jnp.arange(q_block)
        if window > 0:
            band = min(window + q_block, Sk)
            nkb = -(-band // kv_block)
            k_start = jnp.maximum(qi * q_block + q_block - band, 0)
            k_start = jnp.minimum(k_start, Sk - nkb * kv_block)
            k_start = jnp.maximum(k_start, 0)
        else:
            nkb = Sk // kv_block
            k_start = 0

        def kv_step(carry, kb_idx):
            m, l, acc = carry
            start = k_start + kb_idx * kv_block
            ks = jax.lax.dynamic_slice_in_dim(k, start, kv_block, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kv_block, axis=1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qs.astype(F32),
                           ks.astype(F32)) * scale       # (B,KV,G,Bq,Bk)
            k_pos = start + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vs.astype(F32))
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, KV, G, q_block), NEG_INF, F32),
            jnp.zeros((B, KV, G, q_block), F32),
            jnp.zeros((B, KV, G, q_block, D), F32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nkb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,KV,G,Bq,D)
        return jnp.transpose(out, (0, 3, 1, 2, 4))         # (B,Bq,KV,G,D)

    # checkpoint each q block: backward recomputes the kv scan instead of
    # storing per-kv-step residuals (flash-attention backward memory shape).
    # The named scope lets the roofline walker attribute this region's HBM
    # traffic: on TPU it runs as the Pallas flash kernel (VMEM-resident
    # blocks), so its interior traffic collapses to the q/k/v/o I/O.
    with jax.named_scope("flash_attn_interior"):
        outs = jax.lax.map(jax.checkpoint(one_q_block),
                           jnp.arange(nq))                 # (nq,B,Bq,KV,G,D)
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(B, S, H, D)
    return out.astype(q.dtype)


def _flash_mode() -> Optional[bool]:
    """None = off; True = real TPU kernel; False = interpret (tests)."""
    import os
    if os.environ.get("REPRO_FORCE_FLASH") == "1":
        return jax.default_backend() == "tpu"
    return True if jax.default_backend() == "tpu" else None


def attention(x: jax.Array, p: AttnParams, cfg: ModelConfig, pos: jax.Array,
              q_block: int, kv_block: int,
              window_override: Optional[int] = None,
              causal: bool = True, tp_scatter: bool = False) -> jax.Array:
    """Full training/prefill self-attention with output projection.

    On TPU the inner loops run as the Pallas flash kernel (VMEM-resident
    s/p blocks); elsewhere the pure-jnp blockwise path is used (same math,
    validated equal in tests/test_flash_attention.py).
    """
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg, pos)
    # inside attention: gather seq, shard heads (TP); the residual
    # stream between layers stays seq-sharded
    q = shd.act(q, "batch", None, "heads", None)
    # k/v: gather the seq dim BEFORE the block loops — dynamic-slicing a
    # seq-sharded tensor forces involuntary full remat in SPMD
    k = shd.act(k, "batch", None, None, None)
    v = shd.act(v, "batch", None, None, None)
    k = checkpoint_name(k, "kv_gathered")
    v = checkpoint_name(v, "kv_gathered")
    window = cfg.sliding_window if window_override is None else window_override
    if window >= S:
        window = 0  # band covers everything: plain causal
    qb = min(q_block, S)
    kb = min(kv_block, S)
    if S % qb:
        qb = S   # odd lengths (e.g. vlm prefix + text): single block
    if S % kb:
        kb = S
    flash = _flash_mode()
    if flash is not None and S % qb == 0 and k.shape[1] % kb == 0:
        from repro.kernels.flash_attention import flash_attention
        qg = _grouped(q, cfg.n_kv_heads)
        og = flash_attention(qg, k, v, causal, window, qb, kb, not flash)
        o = og.reshape(B, S, -1, cfg.hd)
    else:
        o = blockwise_attention(q, k, v, causal=causal, window=window,
                                q_block=qb, kv_block=kb)
    o = shd.act(o, "batch", None, "heads", None)
    of = o.reshape(B, S, -1)
    if tp_scatter:
        out = shd.tp_out_proj(of, p.wo)
        if out is not None:
            return checkpoint_name(shd.act(out, "batch", "seq", None),
                                   "proj_out")
    out = of @ p.wo
    return checkpoint_name(shd.act(out, "batch", "seq", None), "proj_out")


def cross_attention(x: jax.Array, memory: jax.Array, p: AttnParams,
                    cfg: ModelConfig, q_block: int, kv_block: int) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE on memory side)."""
    B, S, _ = x.shape
    M = memory.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p.wq).reshape(B, S, H, hd)
    k = (memory @ p.wk).reshape(B, M, KV, hd)
    v = (memory @ p.wv).reshape(B, M, KV, hd)
    qb, kb = min(q_block, S), min(kv_block, M)
    if S % qb or M % kb:
        qb, kb = S, M  # tiny shapes: single block
    o = blockwise_attention(q, k, v, causal=False, window=0,
                            q_block=qb, kv_block=kb)
    return o.reshape(B, S, -1) @ p.wo


# ---------------------------------------------------------------------------
# Decode-step attention with KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # (B, S_cache, KV, D)
    v: jax.Array
    # scales are present only for packed (int8/int4) caches
    k_scale: Optional[jax.Array]  # (B, S_cache, KV, 1) f32
    v_scale: Optional[jax.Array]


def cache_specs(bits: int = 16) -> KVCache:
    s = ("batch", "cache_seq", None, None) if bits != 16 else None
    return KVCache(
        k=("batch", "cache_seq", None, None),
        v=("batch", "cache_seq", None, None),
        k_scale=s,
        v_scale=s,
    )


def init_cache(cfg: ModelConfig, batch: int, s_cache: int, bits: int,
               dtype=jnp.bfloat16) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.hd
    if bits == 16:
        z = jnp.zeros((batch, s_cache, KV, hd), dtype)
        return KVCache(z, z, None, None)
    cd = hd if bits == 8 else hd // 2
    z = jnp.zeros((batch, s_cache, KV, cd), jnp.int8)
    s = jnp.ones((batch, s_cache, KV, 1), F32)
    return KVCache(z, z, s, s)


def _quant_rows(x: jax.Array, bits: int):
    """Symmetric per-(pos, head) quantization of (..., D) to int8/int4."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        return q.astype(jnp.int8), scale
    pairs = (q & 0xF).reshape(*q.shape[:-1], -1, 2)  # contiguous, no gather
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.int8), scale


def _dequant_rows(codes: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    c = codes.astype(jnp.int32)
    if bits == 8:
        q = c
    else:
        def sext4(x):
            return ((x & 0xF) ^ 0x8) - 0x8
        q = jnp.stack([sext4(c), sext4(c >> 4)], axis=-1).reshape(
            *c.shape[:-1], -1)
    return q.astype(F32) * scale


def update_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, bits: int) -> KVCache:
    """Insert (B, 1, KV, D) new kv at per-batch position ``pos`` (B,)."""
    if bits == 16:
        upd = functools.partial(jax.lax.dynamic_update_slice_in_dim, axis=0)
        k = jax.vmap(upd)(cache.k, k_new.astype(cache.k.dtype), pos)
        v = jax.vmap(upd)(cache.v, v_new.astype(cache.v.dtype), pos)
        return KVCache(k, v, None, None)
    kq, ks = _quant_rows(k_new, bits)
    vq, vs = _quant_rows(v_new, bits)
    upd = functools.partial(jax.lax.dynamic_update_slice_in_dim, axis=0)
    return KVCache(
        k=jax.vmap(upd)(cache.k, kq, pos),
        v=jax.vmap(upd)(cache.v, vq, pos),
        k_scale=jax.vmap(upd)(cache.k_scale, ks, pos),
        v_scale=jax.vmap(upd)(cache.v_scale, vs, pos),
    )


def decode_attention(x: jax.Array, p: AttnParams, cfg: ModelConfig,
                     cache: KVCache, pos: jax.Array, bits: int,
                     window: int = 0) -> Tuple[jax.Array, KVCache]:
    """One-token attention against the cache.  x: (B, 1, d); pos: (B,).

    When the cache is shorter than the sequence (sliding-window models) it is
    treated as a ring buffer: slot j holds the key written at global position
    ``pos - ((pos - j) mod S_cache)`` — the rolling window that makes
    long_500k decoding O(window) instead of O(S).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = cache.k.shape[1]
    ring = window > 0 and S <= window
    slot = pos % S if ring else pos
    q, k_new, v_new = _qkv(x, p, cfg, pos[:, None])
    cache = update_cache(cache, k_new, v_new, slot, bits)

    # the dequant + attention region deploys as a fused Pallas kernel on TPU
    # (kernels/kvpack dequant fused into flash-decode): codes are read from
    # HBM once, dequantized in VMEM — scoped for the roofline walker.
    # k/v stay in bf16 with f32 MXU accumulation: a whole-cache .astype(F32)
    # gets hoisted out of the layer loop by XLA, doubling cache residency.
    with jax.named_scope("decode_attn_interior"):
        cdt = x.dtype
        if bits == 16:
            k, v = cache.k, cache.v
        else:
            k = _dequant_rows(cache.k, cache.k_scale, bits).astype(cdt)
            v = _dequant_rows(cache.v, cache.v_scale, bits).astype(cdt)
        k = shd.act(k, "batch", "cache_seq", None, None)
        v = shd.act(v, "batch", "cache_seq", None, None)

        j = jnp.arange(S)[None, :]                        # (1, S)
        if ring:
            k_pos = pos[:, None] - ((pos[:, None] - j) % S)
            valid = k_pos >= 0
        else:
            k_pos = j
            valid = k_pos <= pos[:, None]
            if window > 0:
                valid &= (pos[:, None] - k_pos) < window
        qg = _grouped(q, KV).astype(k.dtype)              # (B,1,KV,G,D)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=F32) * (hd ** -0.5)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p_attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p_attn.astype(k.dtype), v,
                       preferred_element_type=F32)        # (B,KV,G,1,D)
    o = jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, 1, H * hd)
    return (o.astype(x.dtype) @ p.wo), cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

class MlpParams(NamedTuple):
    w_gate: Optional[jax.Array]  # (d, ff) — None for gelu
    w_up: jax.Array              # (d, ff)
    w_down: jax.Array            # (ff, d)


def init_mlp(key, d: int, ff: int, act: str, dtype) -> MlpParams:
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    return MlpParams(
        w_gate=(jax.random.normal(k1, (d, ff)) * s).astype(dtype)
        if act == "swiglu" else None,
        w_up=(jax.random.normal(k2, (d, ff)) * s).astype(dtype),
        w_down=(jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    )


def mlp_specs(act: str) -> MlpParams:
    return MlpParams(
        w_gate=("fsdp", "ff") if act == "swiglu" else None,
        w_up=("fsdp", "ff"),
        w_down=("ff", "fsdp"),
    )


def mlp(x: jax.Array, p: MlpParams, act: str,
        tp_scatter: bool = False) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p.w_gate) * (x @ p.w_up)
    else:
        h = jax.nn.gelu(x @ p.w_up)
    h = shd.act(h, "batch", None, "ff")
    if tp_scatter:
        out = shd.tp_out_proj(h, p.w_down)
        if out is not None:
            return checkpoint_name(out, "proj_out")
    return checkpoint_name(h @ p.w_down, "proj_out")


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

class EmbedParams(NamedTuple):
    table: jax.Array        # (V, d)
    unembed: Optional[jax.Array]  # (d, V) — None when tied
    final_norm: jax.Array


def init_embed(key, cfg: ModelConfig, dtype) -> EmbedParams:
    k1, k2 = jax.random.split(key)
    return EmbedParams(
        table=(jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype),
        unembed=None if cfg.tie_embeddings else
        (jax.random.normal(k2, (cfg.d_model, cfg.vocab))
         * cfg.d_model ** -0.5).astype(dtype),
        final_norm=init_rmsnorm(cfg.d_model, dtype),
    )


def embed_specs(cfg: ModelConfig) -> EmbedParams:
    return EmbedParams(
        table=("vocab", "fsdp"),
        unembed=None if cfg.tie_embeddings else ("fsdp", "vocab"),
        final_norm=(None,),
    )


def embed(tokens: jax.Array, p: EmbedParams) -> jax.Array:
    return jnp.take(p.table, tokens, axis=0)


def logits(x: jax.Array, p: EmbedParams, cfg: ModelConfig) -> jax.Array:
    x = rmsnorm(x, p.final_norm, cfg.norm_eps)
    w = p.table.T if cfg.tie_embeddings else p.unembed
    out = x @ w
    # logits are the largest activation: shard S over 'model' when sequence
    # sharding is active (keeps (B, S/tp, V)); otherwise shard the vocab dim
    r = shd.get_rules()
    if r is not None and out.ndim == 3 and \
            r.resolve("seq", out.shape[1]) is not None:
        return shd.act(out, "batch", "seq", None)
    return shd.act(out, "batch", None, "vocab")


def cross_entropy(lg: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; stable in f32."""
    lg = lg.astype(F32)
    m = lg.max(axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def fused_ce_loss(x: jax.Array, p: EmbedParams, cfg: ModelConfig,
                  labels: jax.Array, mask: Optional[jax.Array] = None,
                  chunk: int = 512) -> jax.Array:
    """Unembed + cross-entropy fused over sequence chunks.

    Never materializes the (B, S, V) logits tensor (at 150k vocab that is the
    peak-memory hog of the whole train step): each chunk computes (B, C, V)
    logits with V sharded over 'model', reduces to per-token NLL, and is
    checkpointed so backward recomputes the chunk instead of keeping it.
    """
    B, S, _ = x.shape
    x = rmsnorm(x, p.final_norm, cfg.norm_eps)
    x = shd.act(x, "batch", None, None)             # gather seq for chunking
    w = p.table.T if cfg.tie_embeddings else p.unembed
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)

    @jax.checkpoint
    def one_chunk(xc, lc, mc):
        lg = (xc @ w).astype(F32)
        lg = shd.act(lg, "batch", None, "vocab")
        m = lg.max(axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return jnp.sum(nll), jnp.sum(mc)

    total, count = jnp.zeros((), F32), jnp.zeros((), F32)
    for ci in range(n_chunks):
        lo = ci * chunk
        hi = min(lo + chunk, S)
        mc = (mask[:, lo:hi].astype(F32) if mask is not None
              else jnp.ones((B, hi - lo), F32))
        t, c = one_chunk(x[:, lo:hi], labels[:, lo:hi], mc)
        total, count = total + t, count + c
    return total / jnp.maximum(count, 1.0)
