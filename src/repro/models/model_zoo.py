"""Unified model API: config -> init / loss / prefill / decode / input specs.

``input_specs`` returns ShapeDtypeStructs (never allocates) for every model
input of a given (arch, shape) cell — the dry-run contract.  Modality
frontends are stubs per the assignment: whisper receives precomputed frame
embeddings, internvl receives precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from . import encdec, transformer


class ModelApi(NamedTuple):
    init: Callable          # (key) -> params
    abstract_params: Callable  # () -> params ShapeDtypeStructs
    param_specs: Callable    # () -> logical-axis pytree
    loss_fn: Callable        # (params, batch) -> scalar loss
    prefill: Callable        # (params, batch) -> logits
    decode_step: Callable    # (params, state, tokens) -> (logits, state)
    init_decode_state: Callable  # (batch) -> state
    decode_state_specs: Callable  # () -> logical-axis pytree


def get_api(cfg: ModelConfig, rc: RunConfig) -> ModelApi:
    dtype = rc.jdtype
    if cfg.family == "encdec":
        return ModelApi(
            init=lambda key: encdec.init(key, cfg, dtype),
            abstract_params=lambda: jax.eval_shape(
                lambda: encdec.init(jax.random.PRNGKey(0), cfg, dtype)),
            param_specs=lambda: encdec.param_specs(cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, b, cfg, rc),
            prefill=lambda p, b: encdec.prefill(p, b, cfg, rc),
            decode_step=lambda p, s, t: encdec.decode_step(p, s, t, cfg, rc),
            init_decode_state=lambda batch: encdec.init_decode_state(
                cfg, rc, batch),
            decode_state_specs=lambda: encdec.decode_state_specs(cfg, rc),
        )
    return ModelApi(
        init=lambda key: transformer.init(key, cfg, dtype),
        abstract_params=lambda: jax.eval_shape(
            lambda: transformer.init(jax.random.PRNGKey(0), cfg, dtype)),
        param_specs=lambda: transformer.param_specs(cfg),
        loss_fn=lambda p, b: transformer.loss_fn(p, b, cfg, rc),
        prefill=lambda p, b: transformer.prefill(
            p, b["tokens"], cfg, rc, vis_embeds=b.get("vis_embeds")),
        decode_step=lambda p, s, t: transformer.decode_step(p, s, t, cfg, rc),
        init_decode_state=lambda batch: transformer.init_decode_state(
            cfg, rc, batch),
        decode_state_specs=lambda: transformer.decode_state_specs(cfg, rc),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, rc: RunConfig) -> Dict[str, Any]:
    """Abstract inputs for the cell's step function.

    train / prefill: token batch (+ stub modality embeddings);
    decode: one token per sequence (the KV cache / state is part of the
    lowered function's carried inputs, built via init_decode_state under
    eval_shape).
    """
    B, S = rc.global_batch, rc.seq_len
    i32 = jnp.int32
    if cfg.family == "encdec":
        if rc.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B,), i32)}
        return {
            "frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                           rc.jdtype),
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if rc.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), i32)}
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }
    if cfg.family == "vlm":
        nv = cfg.n_vis_tokens
        out["tokens"] = jax.ShapeDtypeStruct((B, S - nv), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S - nv), i32)
        out["vis_embeds"] = jax.ShapeDtypeStruct((B, nv, cfg.d_model),
                                                 rc.jdtype)
    return out


def batch_logical_specs(cfg: ModelConfig, rc: RunConfig) -> Dict[str, Any]:
    """Logical sharding names for the batch dict."""
    if rc.kind == "decode":
        return {"tokens": ("batch",)}
    out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "encdec":
        out["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        out["vis_embeds"] = ("batch", None, None)
    return out
