"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD: within a chunk the recurrence is computed in its dual quadratic
(attention-like) form on the MXU; across chunks a compact state
(H, N, P) is carried — which is itself a MARS-shaped flow (atomic,
irredundant inter-chunk block), see DESIGN.md §5.

Shapes: d_inner = expand * d_model, P = ssm_head, H = d_inner / P,
N = ssm_state.  B/C are shared across heads (n_groups = 1, as in the 130m
model).  A is per-head scalar decay; dt per head via softplus.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd

F32 = jnp.float32


class SsmParams(NamedTuple):
    in_proj: jax.Array       # (d, 2*di + 2*N + H)
    conv_w: jax.Array        # (K, di + 2*N) depthwise causal conv
    conv_b: jax.Array        # (di + 2*N,)
    a_log: jax.Array         # (H,)
    dt_bias: jax.Array       # (H,)
    d_skip: jax.Array        # (H,)
    gate_norm: jax.Array     # (di,)
    out_proj: jax.Array      # (di, d)


def init_ssm(key, cfg: ModelConfig, dtype) -> SsmParams:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    return SsmParams(
        in_proj=(jax.random.normal(k1, (d, 2 * di + 2 * N + H)) * s).astype(dtype),
        conv_w=(jax.random.normal(k2, (K, di + 2 * N)) * K ** -0.5).astype(dtype),
        conv_b=jnp.zeros((di + 2 * N,), dtype),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, H)).astype(F32),
        dt_bias=jnp.full((H,), -4.6, F32),   # softplus^-1(0.01)
        d_skip=jnp.ones((H,), F32),
        gate_norm=jnp.ones((di,), dtype),
        out_proj=(jax.random.normal(k3, (di, d)) * di ** -0.5).astype(dtype),
    )


def ssm_specs() -> SsmParams:
    return SsmParams(
        in_proj=("fsdp", "tp"), conv_w=(None, "tp"), conv_b=("tp",),
        a_log=(None,), dt_bias=(None,), d_skip=(None,),
        gate_norm=("tp",), out_proj=("tp", "fsdp"),
    )


def _split(zxbcdt: jax.Array, cfg: ModelConfig):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    b = zxbcdt[..., 2 * di:2 * di + N]
    c = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xin, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along S.  x: (B, S, C); w: (K, C).

    Returns (y, new_state) where state holds the last K-1 inputs.
    """
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu(y + b), new_state


def ssd_forward(params: SsmParams, x: jax.Array, cfg: ModelConfig
                ) -> jax.Array:
    """Training/prefill SSD.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, N, H, P, Q = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_head, cfg.ssm_chunk)
    Q = min(Q, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    z, xin, b, c, dt_raw = _split(x @ params.in_proj, cfg)
    xbc, _ = _causal_conv(jnp.concatenate([xin, b, c], axis=-1),
                          params.conv_w, params.conv_b)
    xin, b, c = (xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:])

    dt = jax.nn.softplus(dt_raw.astype(F32) + params.dt_bias)     # (B,S,H)
    a = -jnp.exp(params.a_log)                                    # (H,)
    da = dt * a                                                   # (B,S,H) <0
    # §Perf: the (B,S,H,P)-shaped tensors stream through HBM per layer pass;
    # keep them in the activation dtype and upcast chunk-locally only —
    # measured 9.6 GB/layer-pass of f32 xdt/y traffic otherwise (mamba2
    # train_4k iteration log, EXPERIMENTS.md)
    adt = x.dtype
    xh = xin.reshape(B, S, H, P)
    xdt = xh * dt[..., None].astype(adt)

    # chunk views, scanned one chunk at a time (keeps the dual-form Q x Q
    # tensors chunk-local — the inter-chunk state is the only carried block)
    da_c = jnp.moveaxis(da.reshape(B, nc, Q, H), 1, 0)            # (nc,B,Q,H)
    b_c = jnp.moveaxis(b.reshape(B, nc, Q, N), 1, 0)
    c_c = jnp.moveaxis(c.reshape(B, nc, Q, N), 1, 0)
    xdt_c = jnp.moveaxis(xdt.reshape(B, nc, Q, H, P), 1, 0)
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h, inputs):
        da_n, b_n, c_n, xdt_n = inputs
        b_n, c_n = b_n.astype(F32), c_n.astype(F32)   # chunk-local upcast
        xdt_n = xdt_n.astype(F32)
        cs = jnp.cumsum(da_n, axis=1)                             # (B,Q,H)
        cb = jnp.einsum("bim,bjm->bij", c_n, b_n)                 # (B,Q,Q)
        decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])    # (B,Q,Q,H)
        att = jnp.where(tri[None, :, :, None], cb[..., None] * decay, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, xdt_n)
        y_inter = jnp.einsum("bim,bhmp,bih->bihp", c_n, h, jnp.exp(cs))
        seg = jnp.exp(cs[:, -1:, :] - cs)                         # (B,Q,H)
        s_chunk = jnp.einsum("bjm,bjh,bjhp->bhmp", b_n, seg, xdt_n)
        h_new = jnp.exp(cs[:, -1, :])[:, :, None, None] * h + s_chunk
        return h_new, (y_intra + y_inter).astype(adt)

    init = jnp.zeros((B, H, N, P), F32)
    # scoped for the roofline walker: the chunk-local dual-form tensors are
    # VMEM-resident in the TPU kernelized deployment (see hlo_walk)
    with jax.named_scope("ssd_interior"):
        _, y_c = jax.lax.scan(chunk_step, init, (da_c, b_c, c_c, xdt_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, H, P)               # (B,S,H,P)
    y = y + params.d_skip.astype(adt)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(x.dtype)

    # gate + norm + out
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(y, params.gate_norm, cfg.norm_eps)
    return y @ params.out_proj


# ---------------------------------------------------------------------------
# Decode (recurrent form, O(1) per token)
# ---------------------------------------------------------------------------

class SsmState(NamedTuple):
    h: jax.Array           # (B, H, N, P) f32
    conv: jax.Array        # (B, K-1, di + 2N)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SsmState:
    return SsmState(
        h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head), F32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1,
                        cfg.d_inner + 2 * cfg.ssm_state), F32),
    )


def ssm_state_specs() -> SsmState:
    return SsmState(h=("batch", None, None, None), conv=("batch", None, None))


def ssd_decode(params: SsmParams, x: jax.Array, state: SsmState,
               cfg: ModelConfig) -> Tuple[jax.Array, SsmState]:
    """x: (B, 1, d) -> (y (B, 1, d), new state)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head
    z, xin, b, c, dt_raw = _split(x @ params.in_proj, cfg)
    xbc = jnp.concatenate([xin, b, c], axis=-1)           # (B,1,di+2N)
    conv_in = jnp.concatenate([state.conv.astype(x.dtype), xbc], axis=1)
    y = sum(conv_in[:, i:i + 1, :] * params.conv_w[i]
            for i in range(cfg.ssm_conv))
    xbc_out = jax.nn.silu(y + params.conv_b)
    new_conv = conv_in[:, 1:, :].astype(F32)
    xin, b, c = (xbc_out[..., :di], xbc_out[..., di:di + N],
                 xbc_out[..., di + N:])

    dt = jax.nn.softplus(dt_raw[:, 0].astype(F32) + params.dt_bias)  # (B,H)
    a = -jnp.exp(params.a_log)
    da = jnp.exp(dt * a)                                  # (B,H)
    xh = xin[:, 0].reshape(B, H, P).astype(F32)
    bx = jnp.einsum("bm,bhp->bhmp", b[:, 0].astype(F32), xh * dt[..., None])
    h = da[:, :, None, None] * state.h + bx
    yh = jnp.einsum("bm,bhmp->bhp", c[:, 0].astype(F32), h)
    yh = yh + params.d_skip[None, :, None] * xh
    yflat = yh.reshape(B, 1, di).astype(x.dtype)
    yflat = yflat * jax.nn.silu(z)
    from .layers import rmsnorm
    yflat = rmsnorm(yflat, params.gate_norm, cfg.norm_eps)
    return yflat @ params.out_proj, SsmState(h=h, conv=new_conv)
