"""Generic decoder-only LM frame: dense / vlm / moe / ssm / hybrid families.

One scan-over-stacked-layers body serves every decoder family (the HLO holds
a single layer regardless of depth — essential for the 80-layer dry-runs);
family-specific sublayers (attention, SSD mixer, MoE block) are selected
statically from the config, and unused param fields are None.

Whisper's encoder-decoder lives in models/encdec.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import sharding as shd
from . import layers as L
from . import moe as M
from . import ssm as S


class LayerParams(NamedTuple):
    ln1: jax.Array
    attn: Optional[L.AttnParams]
    ssm: Optional[S.SsmParams]
    ln_attn_out: Optional[jax.Array]   # hymba per-branch norms
    ln_ssm_out: Optional[jax.Array]
    ln2: Optional[jax.Array]
    mlp: Optional[L.MlpParams]
    moe: Optional[M.MoeParams]


class DenseParams(NamedTuple):
    embed: L.EmbedParams
    layers: LayerParams      # stacked: leading dim n_layers


def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "vlm", "moe", "hybrid", "encdec")


def _has_ssm(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def _has_mlp(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "vlm", "hybrid", "encdec")


def init_layer(key, cfg: ModelConfig, dtype) -> LayerParams:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return LayerParams(
        ln1=L.init_rmsnorm(d, dtype),
        attn=L.init_attn(k1, cfg, dtype) if _has_attn(cfg) else None,
        ssm=S.init_ssm(k3, cfg, dtype) if _has_ssm(cfg) else None,
        ln_attn_out=L.init_rmsnorm(d, dtype) if cfg.family == "hybrid" else None,
        ln_ssm_out=L.init_rmsnorm(d, dtype) if cfg.family == "hybrid" else None,
        ln2=L.init_rmsnorm(d, dtype) if (_has_mlp(cfg) or cfg.family == "moe")
        else None,
        mlp=L.init_mlp(k2, d, cfg.d_ff, cfg.mlp_act, dtype)
        if _has_mlp(cfg) else None,
        moe=M.init_moe(k2, cfg, dtype) if cfg.family == "moe" else None,
    )


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> DenseParams:
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(lkeys)
    return DenseParams(embed=L.init_embed(ke, cfg, dtype), layers=stacked)


def layer_specs(cfg: ModelConfig) -> LayerParams:
    return LayerParams(
        ln1=(None,),
        attn=L.attn_specs(cfg) if _has_attn(cfg) else None,
        ssm=S.ssm_specs() if _has_ssm(cfg) else None,
        ln_attn_out=(None,) if cfg.family == "hybrid" else None,
        ln_ssm_out=(None,) if cfg.family == "hybrid" else None,
        ln2=(None,) if (_has_mlp(cfg) or cfg.family == "moe") else None,
        mlp=L.mlp_specs(cfg.mlp_act) if _has_mlp(cfg) else None,
        moe=M.moe_specs() if cfg.family == "moe" else None,
    )


def param_specs(cfg: ModelConfig) -> DenseParams:
    stacked = jax.tree.map(lambda t: (None,) + t, layer_specs(cfg),
                           is_leaf=shd._is_logical_leaf)
    return DenseParams(embed=L.embed_specs(cfg), layers=stacked)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, rc: RunConfig, x, pos, lp: LayerParams):
    """Returns (x, aux_loss_increment)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, lp.ln1, cfg.norm_eps)
    if cfg.family == "hybrid":
        a = L.attention(h, lp.attn, cfg, pos, rc.q_block, rc.kv_block,
                        tp_scatter=rc.tp_scatter)
        s = S.ssd_forward(lp.ssm, h, cfg)
        mix = 0.5 * (L.rmsnorm(a, lp.ln_attn_out, cfg.norm_eps)
                     + L.rmsnorm(s, lp.ln_ssm_out, cfg.norm_eps))
        x = x + mix
    elif cfg.family == "ssm":
        x = x + S.ssd_forward(lp.ssm, h, cfg)
    else:
        x = x + L.attention(h, lp.attn, cfg, pos, rc.q_block, rc.kv_block,
                            tp_scatter=rc.tp_scatter)
    if lp.ln2 is not None:
        h2 = L.rmsnorm(x, lp.ln2, cfg.norm_eps)
        if cfg.family == "moe":
            out, aux = M.moe_block(h2, lp.moe, cfg)
            x = x + out
        else:
            x = x + L.mlp(h2, lp.mlp, cfg.mlp_act, tp_scatter=rc.tp_scatter)
    return shd.act(x, "batch", "seq", None), aux


def backbone(params: DenseParams, tokens: jax.Array, cfg: ModelConfig,
             rc: RunConfig, vis_embeds: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S_text) [+ optional stub prefix] -> (final hidden x, aux)."""
    x = L.embed(tokens, params.embed)
    if vis_embeds is not None:
        x = jnp.concatenate([vis_embeds.astype(x.dtype), x], axis=1)
    B, Sq, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
    x = shd.act(x, "batch", "seq", None)

    body = functools.partial(_layer_fwd, cfg, rc)
    if rc.remat:
        if rc.remat_policy == "save_collectives":
            policy = jax.checkpoint_policies.save_only_these_names(
                "proj_out", "kv_gathered")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body = jax.checkpoint(body, policy=policy)

    def scan_fn(carry, lp):
        x, aux = carry
        x, aux_inc = body(x, pos, lp)
        return (x, aux + aux_inc), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                               params.layers)
    return x, aux / cfg.n_layers


def forward(params: DenseParams, tokens: jax.Array, cfg: ModelConfig,
            rc: RunConfig, vis_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Full logits (tests / tiny shapes; the loss path never calls this)."""
    x, aux = backbone(params, tokens, cfg, rc, vis_embeds)
    return L.logits(x, params.embed, cfg), aux


def loss_fn(params: DenseParams, batch, cfg: ModelConfig, rc: RunConfig):
    """batch: dict(tokens (B,S), labels (B,S) [, vis_embeds])."""
    vis = batch.get("vis_embeds")
    x, aux = backbone(params, batch["tokens"], cfg, rc, vis_embeds=vis)
    if vis is not None:
        x = x[:, vis.shape[1]:]              # loss over text positions only
    loss = L.fused_ce_loss(x, params.embed, cfg, batch["labels"],
                           batch.get("mask"))
    if cfg.family == "moe":
        loss = loss + 0.01 * aux
    return loss


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class LayerCache(NamedTuple):
    kv: Optional[L.KVCache]
    ssm: Optional[S.SsmState]


class DecodeState(NamedTuple):
    caches: LayerCache       # stacked over layers
    pos: jax.Array           # (B,) next position per sequence


def init_decode_state(cfg: ModelConfig, rc: RunConfig, batch: int) -> DecodeState:
    s_cache = rc.seq_len
    if cfg.sliding_window:
        s_cache = min(s_cache, cfg.sliding_window)
    one = LayerCache(
        kv=jax.eval_shape(lambda: L.init_cache(
            cfg, batch, s_cache, rc.kv_cache_bits, rc.jdtype))
        if _has_attn(cfg) else None,
        ssm=jax.eval_shape(lambda: S.init_ssm_state(cfg, batch))
        if _has_ssm(cfg) else None,
    )
    cache = jax.tree.map(
        lambda s: jnp.zeros((cfg.n_layers,) + s.shape, s.dtype), one)
    return DecodeState(caches=cache, pos=jnp.zeros((batch,), jnp.int32))


def decode_state_specs(cfg: ModelConfig, rc: RunConfig) -> DecodeState:
    one = LayerCache(
        kv=L.cache_specs(rc.kv_cache_bits) if _has_attn(cfg) else None,
        ssm=S.ssm_state_specs() if _has_ssm(cfg) else None,
    )
    stacked = jax.tree.map(lambda t: (None,) + t, one,
                           is_leaf=shd._is_logical_leaf)
    return DecodeState(caches=stacked, pos=(None,))


def decode_step(params: DenseParams, state: DecodeState, tokens: jax.Array,
                cfg: ModelConfig, rc: RunConfig) -> Tuple[jax.Array, DecodeState]:
    """One decode step.  tokens: (B,) -> (logits (B, V), new state)."""
    x = L.embed(tokens[:, None], params.embed)            # (B, 1, d)

    def scan_fn(x, layer):
        lp, cache = layer
        h = L.rmsnorm(x, lp.ln1, cfg.norm_eps)
        new_kv, new_ssm = cache.kv, cache.ssm
        if cfg.family == "hybrid":
            a, new_kv = L.decode_attention(h, lp.attn, cfg, cache.kv,
                                           state.pos, rc.kv_cache_bits,
                                           cfg.sliding_window)
            s, new_ssm = S.ssd_decode(lp.ssm, h, cache.ssm, cfg)
            x = x + 0.5 * (L.rmsnorm(a, lp.ln_attn_out, cfg.norm_eps)
                           + L.rmsnorm(s, lp.ln_ssm_out, cfg.norm_eps))
        elif cfg.family == "ssm":
            s, new_ssm = S.ssd_decode(lp.ssm, h, cache.ssm, cfg)
            x = x + s
        else:
            a, new_kv = L.decode_attention(h, lp.attn, cfg, cache.kv,
                                           state.pos, rc.kv_cache_bits,
                                           cfg.sliding_window)
            x = x + a
        if lp.ln2 is not None:
            h2 = L.rmsnorm(x, lp.ln2, cfg.norm_eps)
            if cfg.family == "moe":
                out, _ = M.moe_block(h2, lp.moe, cfg)
                x = x + out
            else:
                x = x + L.mlp(h2, lp.mlp, cfg.mlp_act)
        return x, LayerCache(kv=new_kv, ssm=new_ssm)

    x, caches = jax.lax.scan(scan_fn, x, (params.layers, state.caches))
    lg = L.logits(x, params.embed, cfg)[:, 0]
    return lg, DecodeState(caches=caches, pos=state.pos + 1)


def prefill(params: DenseParams, tokens: jax.Array, cfg: ModelConfig,
            rc: RunConfig, vis_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Prefill: logits for the LAST position only (serving semantics —
    materializing (B, S, 150k-vocab) logits would dwarf the model)."""
    x, _ = backbone(params, tokens, cfg, rc, vis_embeds=vis_embeds)
    return L.logits(x[:, -1:], params.embed, cfg)[:, 0]
