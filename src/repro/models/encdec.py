"""Whisper-style encoder-decoder backbone (conv/mel frontend stubbed).

``input_specs()`` supplies precomputed frame embeddings (B, enc_seq, d) — the
assignment's frontend-stub contract.  The encoder is bidirectional
self-attention; the decoder adds causal self-attention + cross-attention.
Decode keeps a self-attn KV cache per layer plus the cross-attn K/V computed
once from the encoder memory ("prefill").
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import sharding as shd
from . import layers as L


class EncLayer(NamedTuple):
    ln1: jax.Array
    attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MlpParams


class DecLayer(NamedTuple):
    ln1: jax.Array
    self_attn: L.AttnParams
    ln_x: jax.Array
    cross_attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MlpParams


class EncDecParams(NamedTuple):
    embed: L.EmbedParams          # decoder token embeddings + unembed
    enc_layers: EncLayer          # stacked enc_layers
    enc_norm: jax.Array
    dec_layers: DecLayer          # stacked n_layers


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> EncLayer:
    k1, k2 = jax.random.split(key)
    return EncLayer(
        ln1=L.init_rmsnorm(cfg.d_model, dtype),
        attn=L.init_attn(k1, cfg, dtype),
        ln2=L.init_rmsnorm(cfg.d_model, dtype),
        mlp=L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype),
    )


def _init_dec_layer(key, cfg: ModelConfig, dtype) -> DecLayer:
    k1, k2, k3 = jax.random.split(key, 3)
    return DecLayer(
        ln1=L.init_rmsnorm(cfg.d_model, dtype),
        self_attn=L.init_attn(k1, cfg, dtype),
        ln_x=L.init_rmsnorm(cfg.d_model, dtype),
        cross_attn=L.init_attn(k2, cfg, dtype),
        ln2=L.init_rmsnorm(cfg.d_model, dtype),
        mlp=L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype),
    )


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> EncDecParams:
    ke, k1, k2 = jax.random.split(key, 3)
    ek = jax.random.split(k1, cfg.enc_layers)
    dk = jax.random.split(k2, cfg.n_layers)
    return EncDecParams(
        embed=L.init_embed(ke, cfg, dtype),
        enc_layers=jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(ek),
        enc_norm=L.init_rmsnorm(cfg.d_model, dtype),
        dec_layers=jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dk),
    )


def param_specs(cfg: ModelConfig) -> EncDecParams:
    def stack(t):
        return jax.tree.map(lambda x: (None,) + x, t,
                            is_leaf=shd._is_logical_leaf)
    enc = EncLayer(ln1=(None,), attn=L.attn_specs(cfg), ln2=(None,),
                   mlp=L.mlp_specs(cfg.mlp_act))
    dec = DecLayer(ln1=(None,), self_attn=L.attn_specs(cfg), ln_x=(None,),
                   cross_attn=L.attn_specs(cfg), ln2=(None,),
                   mlp=L.mlp_specs(cfg.mlp_act))
    return EncDecParams(embed=L.embed_specs(cfg), enc_layers=stack(enc),
                        enc_norm=(None,), dec_layers=stack(dec))


def encode(params: EncDecParams, frames: jax.Array, cfg: ModelConfig,
           rc: RunConfig) -> jax.Array:
    """frames: (B, enc_seq, d) stub embeddings -> encoder memory."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = frames

    def body(x, lp: EncLayer):
        h = L.rmsnorm(x, lp.ln1, cfg.norm_eps)
        qb = S if S % min(rc.q_block, S) else min(rc.q_block, S)
        kb = S if S % min(rc.kv_block, S) else min(rc.kv_block, S)
        x = x + L.attention(h, lp.attn, cfg, pos, qb, kb, causal=False)
        h = L.rmsnorm(x, lp.ln2, cfg.norm_eps)
        return x + L.mlp(h, lp.mlp, cfg.mlp_act)

    if rc.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x,
                        params.enc_layers)
    return L.rmsnorm(x, params.enc_norm, cfg.norm_eps)


def decoder_backbone(params: EncDecParams, tokens: jax.Array,
                     memory: jax.Array, cfg: ModelConfig, rc: RunConfig
                     ) -> jax.Array:
    B, S = tokens.shape
    x = L.embed(tokens, params.embed)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, lp: DecLayer):
        h = L.rmsnorm(x, lp.ln1, cfg.norm_eps)
        qb = min(rc.q_block, S) if S % min(rc.q_block, S) == 0 else S
        kb = min(rc.kv_block, S) if S % min(rc.kv_block, S) == 0 else S
        x = x + L.attention(h, lp.self_attn, cfg, pos, qb, kb)
        h = L.rmsnorm(x, lp.ln_x, cfg.norm_eps)
        x = x + L.cross_attention(h, memory, lp.cross_attn, cfg,
                                  rc.q_block, rc.kv_block)
        h = L.rmsnorm(x, lp.ln2, cfg.norm_eps)
        return x + L.mlp(h, lp.mlp, cfg.mlp_act)

    if rc.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x,
                        params.dec_layers)
    return x


def decoder_forward(params: EncDecParams, tokens: jax.Array,
                    memory: jax.Array, cfg: ModelConfig, rc: RunConfig
                    ) -> jax.Array:
    """Full logits (tests); serving uses last-position prefill below."""
    x = decoder_backbone(params, tokens, memory, cfg, rc)
    return L.logits(x, params.embed, cfg)


def prefill(params: EncDecParams, batch, cfg: ModelConfig,
            rc: RunConfig) -> jax.Array:
    memory = encode(params, batch["frames"], cfg, rc)
    x = decoder_backbone(params, batch["tokens"], memory, cfg, rc)
    return L.logits(x[:, -1:], params.embed, cfg)[:, 0]


def loss_fn(params: EncDecParams, batch, cfg: ModelConfig, rc: RunConfig):
    """batch: dict(frames (B,enc_seq,d), tokens (B,S), labels (B,S))."""
    memory = encode(params, batch["frames"], cfg, rc)
    x = decoder_backbone(params, batch["tokens"], memory, cfg, rc)
    return L.fused_ce_loss(x, params.embed, cfg, batch["labels"],
                           batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class EncDecDecodeState(NamedTuple):
    self_kv: L.KVCache        # stacked over dec layers
    cross_k: jax.Array        # (L, B, enc_seq, KV, hd) — computed at prefill
    cross_v: jax.Array
    pos: jax.Array


def init_decode_state(cfg: ModelConfig, rc: RunConfig, batch: int
                      ) -> EncDecDecodeState:
    one = jax.eval_shape(lambda: L.init_cache(
        cfg, batch, rc.seq_len, rc.kv_cache_bits, rc.jdtype))
    kv = jax.tree.map(
        lambda s: jnp.zeros((cfg.n_layers,) + s.shape, s.dtype), one)
    ck = jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd),
                   rc.jdtype)
    return EncDecDecodeState(self_kv=kv, cross_k=ck, cross_v=ck,
                             pos=jnp.zeros((batch,), jnp.int32))


def decode_state_specs(cfg: ModelConfig, rc: RunConfig) -> EncDecDecodeState:
    cs = jax.tree.map(lambda t: (None,) + t, L.cache_specs(rc.kv_cache_bits),
                      is_leaf=shd._is_logical_leaf)
    return EncDecDecodeState(
        self_kv=cs,
        cross_k=(None, "batch", None, None, None),
        cross_v=(None, "batch", None, None, None),
        pos=(None,),
    )


def decode_step(params: EncDecParams, state: EncDecDecodeState,
                tokens: jax.Array, cfg: ModelConfig, rc: RunConfig
                ) -> Tuple[jax.Array, EncDecDecodeState]:
    x = L.embed(tokens[:, None], params.embed)
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def scan_fn(x, layer):
        lp, kv, ck, cv = layer
        h = L.rmsnorm(x, lp.ln1, cfg.norm_eps)
        a, kv = L.decode_attention(h, lp.self_attn, cfg, kv, state.pos,
                                   rc.kv_cache_bits)
        x = x + a
        # cross attention against precomputed memory K/V
        h = L.rmsnorm(x, lp.ln_x, cfg.norm_eps)
        q = (h @ lp.cross_attn.wq).reshape(B, 1, H, hd)
        qg = q.reshape(B, 1, KV, H // KV, hd).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck.astype(jnp.float32))
        p_attn = jax.nn.softmax(s * hd ** -0.5, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p_attn, cv.astype(jnp.float32))
        o = jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, 1, H * hd)
        x = x + o.astype(x.dtype) @ lp.cross_attn.wo
        h = L.rmsnorm(x, lp.ln2, cfg.norm_eps)
        x = x + L.mlp(h, lp.mlp, cfg.mlp_act)
        return x, kv

    x, kv = jax.lax.scan(
        scan_fn, x, (params.dec_layers, state.self_kv,
                     state.cross_k, state.cross_v))
    lg = L.logits(x, params.embed, cfg)[:, 0]
    return lg, EncDecDecodeState(self_kv=kv, cross_k=state.cross_k,
                                 cross_v=state.cross_v, pos=state.pos + 1)
