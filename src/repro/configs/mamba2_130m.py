"""Mamba2-130M — SSD state-space duality, attention-free [arXiv:2405.21060]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, ssm_state=128, ssm_expand=2, ssm_head=64,
    ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256, ssm_state=16, ssm_expand=2, ssm_head=32,
        ssm_conv=4, ssm_chunk=32,
    )
