"""Whisper-tiny — encoder-decoder audio backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB: ``input_specs()`` provides 1500 precomputed
frame embeddings (30 s at 50 Hz after the conv stride-2).  GELU MLP, full MHA
(n_kv_heads == n_heads), learned-position-free backbone (we use RoPE in this
framework's backbone; divergence noted in DESIGN.md — the backbone contract
is shapes + family, per the assignment).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, mlp_act="gelu",
    enc_layers=4, enc_seq=1500,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=256, mlp_act="gelu", enc_layers=2, enc_seq=64,
    )
