"""Grok-1 314B — MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, n_experts=8, topk=2, head_dim=128,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, n_experts=4, topk=2,
    )
