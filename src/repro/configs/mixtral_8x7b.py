"""Mixtral 8x7B — MoE 8 experts top-2, sliding-window attn [arXiv:2401.04088]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, n_experts=8, topk=2,
    sliding_window=4096, rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, n_experts=4, topk=2, sliding_window=64,
    )
