"""Qwen1.5-110B — dense GQA with QKV bias [hf:Qwen/Qwen1.5-110B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab=152064, qkv_bias=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=384, vocab=256, qkv_bias=True,
    )
