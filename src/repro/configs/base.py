"""Config system: model architecture + run (shape/parallelism/feature) configs.

Every assigned architecture has a module ``configs/<id>.py`` exposing
``CONFIG: ModelConfig`` with the exact published hyper-parameters plus
``smoke()`` returning a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False       # qwen1.5
    mlp_act: str = "swiglu"      # swiglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- attention variants ---
    sliding_window: int = 0      # 0 = full causal
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0             # fixed encoder length (frames), frontend stub
    # --- VLM ---
    n_vis_tokens: int = 0        # stub patch-embedding prefix length

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d if H else 0
        mlp = 3 * d * ff if self.mlp_act == "swiglu" else 2 * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp + 2 * d
        elif self.family == "moe":
            per_layer = attn + self.n_experts * mlp + d * self.n_experts + 2 * d
        elif self.family == "ssm":
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * N + Hs) + di * d + 2 * d
        elif self.family == "hybrid":
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * N + Hs) + di * d
            per_layer = attn + ssm + mlp + 2 * d
        elif self.family == "encdec":
            per_layer = attn + mlp + 2 * d  # decoder layer; encoder added below
        n = L * per_layer + V * d * (1 if self.tie_embeddings else 2) + d
        if self.family == "encdec":
            n += self.enc_layers * (attn + mlp + 2 * d) + L * (attn + d)  # cross-attn
        if self.family == "vlm":
            n += self.n_vis_tokens  # stub frontend is excluded by design
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: topk experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        mlp = 3 * d * ff
        per_layer = attn + self.topk * mlp + d * self.n_experts + 2 * d
        return L * per_layer + self.vocab * d * 2 + d


#: shape_id -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution configuration for one (arch x shape x mesh) cell."""
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    # parallelism
    fsdp: bool = True              # shard params/opt over the data axis
    seq_shard: bool = True         # shard activations' seq dim over 'model'
    pipeline_stages: int = 1       # >1: GPipe over the pod axis
    microbatches: int = 1
    # numerics / memory
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"     # bfloat16 for the largest archs
    remat: bool = True
    # 'full' recomputes everything; 'save_collectives' saves tensors whose
    # recomputation would replay collectives (attn/mlp outs, gathered kv)
    remat_policy: str = "full"
    # attention blocking
    q_block: int = 512
    kv_block: int = 1024
    # §Perf: hand-scheduled reduce-scatter TP out-projections (shard_map)
    # instead of SPMD-chosen all-reduce+all-gather pairs
    tp_scatter: bool = False
    # vocab-dim sharding of embed/unembed
    shard_vocab: bool = True
    # paper-technique features
    grad_compress_bits: int = 0    # 0 = off; 8 = cross-pod compressed grads
    kv_cache_bits: int = 16        # 16 = bf16; 8/4 = packed (paper packing)
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32


ARCH_IDS = (
    "tinyllama-1.1b", "qwen1.5-110b", "yi-9b", "granite-8b", "mamba2-130m",
    "grok-1-314b", "mixtral-8x7b", "internvl2-76b", "whisper-tiny",
    "hymba-1.5b",
)


def load_arch(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def load_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.smoke()


def run_config_for(shape_id: str, cfg: ModelConfig, **overrides) -> RunConfig:
    seq, batch, kind = SHAPES[shape_id]
    big = cfg.param_count() > 50e9
    defaults = dict(
        seq_len=seq, global_batch=batch, kind=kind,
        opt_dtype="bfloat16" if big else "float32",
    )
    defaults.update(overrides)
    return RunConfig(**defaults)
