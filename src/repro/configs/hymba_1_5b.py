"""Hymba-1.5B — hybrid parallel attention + mamba heads [arXiv:2411.13676].

Each layer runs GQA attention and an SSM mixer in parallel on the same input
and averages the branch outputs after per-branch normalization.  Most layers
use sliding-window attention in the published model; we use a uniform 1024
window (global-attn exception layers and meta-tokens are noted as
simplifications in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_head=64, ssm_chunk=256,
    sliding_window=1024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, head_dim=32,
        ssm_state=16, ssm_expand=2, ssm_head=32, ssm_chunk=32,
        sliding_window=64,
    )
