"""InternVL2-76B backbone (InternLM2-style decoder) [arXiv:2404.16821].

The InternViT frontend is a STUB per the assignment: ``input_specs()``
provides 256 precomputed patch-embedding tokens prepended to the text.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, n_vis_tokens=256,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, n_vis_tokens=8,
    )
