"""I/O transfer-cycle model (paper §5 protocol: on-FPGA cycle counters).

Models an AXI-style bus: a transaction (burst) of ``n`` bits costs
``init + ceil(n / bus_bits)`` cycles, with bursts capped at ``max_beats``
beats (AXI4: 256), long transfers paying the init latency once per burst.
Peak bandwidth = one beat/cycle, so *cycles* directly measure bandwidth
utilization — the paper's figure of merit.

Access patterns over the same tile I/O, mirroring §5.1.1:

* ``minimal``   — exact footprint on the original array layout, bursts where
                  the footprint happens to be contiguous (HLS-inferred);
* ``bbox``      — rectangular bounding box per array row (PolyOpt/HLS-style),
                  simple enough to always burst but transfers extra data;
* ``mars``      — MARS layout of §3.2 (ILP-coalesced bursts), padded words;
* ``mars_pack`` — MARS layout, bit-packed words (§2.4), no compression;
* ``mars_comp`` — compressed + packed MARS (§3.3), sizes from real data,
                  plus the bounded one-aligned-word slop per transaction end
                  (§3.3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.obs import instrument as obs

from . import compression as comp
from . import packing
from .layout import LayoutResult
from .mars import MarsAnalysis, analyze
from .stencil import StencilSpec, stencil_values


@dataclasses.dataclass(frozen=True)
class TransferModel:
    bus_bits: int = 64
    burst_init: int = 8
    max_beats: int = 256

    def transaction_cycles(self, bits: int) -> int:
        if bits <= 0:
            return 0
        beats = -(-bits // self.bus_bits)
        bursts = -(-beats // self.max_beats)
        return self.burst_init * bursts + beats


# ---------------------------------------------------------------------------
# Original-allocation mapping (per benchmark)
# ---------------------------------------------------------------------------

def original_cells(name: str, points: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Map iteration points to (row_keys, innermost) original array cells.

    Row keys identify memory rows of the original allocation; the innermost
    coordinate is contiguous in memory within a row.
    """
    pts = np.asarray(points, dtype=np.int64)
    if name == "jacobi-1d":
        t, i = pts[:, 0], pts[:, 1]
        rows = (t % 2)[:, None]              # A/B ping-pong arrays
        return rows, i
    if name == "jacobi-2d":
        t, u, v = pts[:, 0], pts[:, 1], pts[:, 2]
        i, j = u - t, v - t
        rows = np.stack([t % 2, i], axis=1)
        return rows, j
    if name == "seidel-2d":
        t, u, v = pts[:, 0], pts[:, 1], pts[:, 2]
        i = u - 2 * t
        j = v - 3 * t - 2 * i
        rows = i[:, None]                    # single in-place array
        return rows, j
    raise KeyError(name)


def _dedup_cells(rows: np.ndarray, inner: np.ndarray):
    key = np.unique(np.concatenate([rows, inner[:, None]], axis=1), axis=0)
    return key[:, :-1], key[:, -1]


def _runs(rows: np.ndarray, inner: np.ndarray) -> List[int]:
    """Lengths of maximal contiguous runs within each row.

    Sorted with the row key as the *primary* lexsort key and the innermost
    (memory-contiguous) coordinate secondary, so adjacent cells of one row
    coalesce into a single run — ``rows=[0,0,0,1], inner=[0,1,2,0]`` is two
    runs ``[3, 1]``, not three.
    """
    if len(inner) == 0:
        return []
    # np.lexsort's LAST key is primary: pass (inner, ..., rows_0) so the
    # sort is lexicographic by row key first, innermost coordinate last
    keys = np.concatenate([rows, inner[:, None]], axis=1)
    order = np.lexsort(keys.T[::-1])
    rows_s, inner_s = rows[order], inner[order]
    same_row = np.all(rows_s[1:] == rows_s[:-1], axis=1)
    contiguous = same_row & (inner_s[1:] == inner_s[:-1] + 1)
    breaks = np.flatnonzero(~contiguous)
    edges = np.concatenate(([-1], breaks, [len(inner_s) - 1]))
    return [int(r) for r in np.diff(edges)]


def _bbox_bits(rows: np.ndarray, inner: np.ndarray, padded: int) -> List[int]:
    """Bounding-box transfer: one burst per distinct row key, full bbox width."""
    uniq = np.unique(rows, axis=0)
    width = int(inner.max() - inner.min() + 1)
    return [width * padded] * len(uniq)


# ---------------------------------------------------------------------------
# Per-tile I/O cycle accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileIO:
    read_cycles: int
    write_cycles: int
    read_bits: int
    write_bits: int
    read_transactions: int
    write_transactions: int

    @property
    def total_cycles(self) -> int:
        return self.read_cycles + self.write_cycles


class TileIOModel:
    """Per-tile I/O accounting for one stencil + tiling + layout.

    Caches the per-tile MARS analyses (the representative tile and its
    producer tiles) so repeated dtype/mode queries are cheap.
    """

    def __init__(self, spec: StencilSpec, analysis: MarsAnalysis,
                 layout_result: LayoutResult,
                 rep_tile: Tuple[int, ...] | None = None,
                 model: TransferModel = TransferModel()):
        self.spec = spec
        self.model = model
        self.order = list(layout_result.order)
        self.a = analysis if rep_tile is None else analyze(spec, rep_tile)
        c0 = self.a.spec.tile_of(self.a.out_mars[0].points[:1])[0]
        self._producers: Dict[Tuple[int, ...], MarsAnalysis] = {}
        for producer_off in self.a.consumed:
            rep = tuple(int(x) for x in (c0 + np.asarray(producer_off)))
            self._producers[producer_off] = analyze(spec, rep)

    # -- geometry ----------------------------------------------------------
    def input_mars_points(self) -> List[np.ndarray]:
        """Whole consumed MARS point sets, from the true producer tiles."""
        out: List[np.ndarray] = []
        for producer_off, mars_ids in self.a.consumed.items():
            pa = self._producers[producer_off]
            out.extend(pa.out_mars[mid].points for mid in mars_ids)
        return out

    def output_mars_points(self) -> List[np.ndarray]:
        return [m.points for m in self.a.out_mars]

    def coalesced_read_bursts(self) -> List[List[Tuple[Tuple[int, ...], int]]]:
        """Bursts as lists of (producer_offset, mars_id), per layout runs."""
        pos = {m: k for k, m in enumerate(self.order)}
        bursts: List[List[Tuple[Tuple[int, ...], int]]] = []
        for producer_off, mars_ids in self.a.consumed.items():
            ks = sorted(pos[m] for m in mars_ids)
            cur: List[Tuple[Tuple[int, ...], int]] = []
            prev = None
            for kpos in ks:
                if prev is not None and kpos != prev + 1:
                    bursts.append(cur)
                    cur = []
                cur.append((producer_off, self.order[kpos]))
                prev = kpos
            if cur:
                bursts.append(cur)
        return bursts

    def _values(self, points: np.ndarray, hist: np.ndarray) -> np.ndarray:
        return stencil_values(self.spec.name, hist, points)

    def _compressed_bits(self, points: np.ndarray, dtype: str,
                         hist: np.ndarray) -> int:
        words, nbits = comp.words_for(self._values(points, hist), dtype)
        return comp.compressed_cost_bits(words, nbits)

    # -- accounting --------------------------------------------------------
    def tile_io(self, dtype: str, mode: str,
                hist: np.ndarray | None = None) -> TileIO:
        nbits, padded = packing.dtype_widths(dtype)
        in_pts = self.input_mars_points()
        out_pts = self.output_mars_points()

        if mode == "minimal":
            rows, inner = original_cells(
                self.spec.name, np.concatenate(in_pts, axis=0))
            rows, inner = _dedup_cells(rows, inner)
            rbits = [r * padded for r in _runs(rows, inner)]
            orow, oinn = original_cells(
                self.spec.name, np.concatenate(out_pts, axis=0))
            orow, oinn = _dedup_cells(orow, oinn)
            wbits = [r * padded for r in _runs(orow, oinn)]
        elif mode == "bbox":
            rows, inner = original_cells(
                self.spec.name, np.concatenate(in_pts, axis=0))
            rbits = _bbox_bits(rows, inner, padded)
            orow, oinn = original_cells(
                self.spec.name, np.concatenate(out_pts, axis=0))
            wbits = _bbox_bits(orow, oinn, padded)
        elif mode in ("mars", "mars_pack", "mars_comp"):
            width = padded if mode == "mars" else nbits
            rbits = []
            for burst in self.coalesced_read_bursts():
                if mode == "mars_comp":
                    assert hist is not None, "mars_comp needs stencil data"
                    bits = sum(
                        self._compressed_bits(
                            self._producers[off].out_mars[mid].points,
                            dtype, hist)
                        for off, mid in burst)
                    bits += 2 * self.model.bus_bits  # §3.3.2 alignment slop
                else:
                    bits = sum(
                        self._producers[off].out_mars[mid].points.shape[0] * width
                        for off, mid in burst)
                rbits.append(bits)
            if mode == "mars_comp":
                assert hist is not None
                wtotal = sum(self._compressed_bits(p, dtype, hist)
                             for p in out_pts) + 2 * self.model.bus_bits
            else:
                wtotal = sum(p.shape[0] for p in out_pts) * width
            wbits = [wtotal]
        else:
            raise KeyError(mode)

        io = TileIO(
            read_cycles=sum(self.model.transaction_cycles(b) for b in rbits),
            write_cycles=sum(self.model.transaction_cycles(b) for b in wbits),
            read_bits=int(sum(rbits)),
            write_bits=int(sum(wbits)),
            read_transactions=len(rbits),
            write_transactions=len(wbits),
        )
        if obs.enabled():
            self._publish_io(io, dtype, mode, rbits, wbits)
        return io

    def _publish_io(self, io: TileIO, dtype: str, mode: str,
                    rbits: Sequence[int], wbits: Sequence[int]) -> None:
        """Emit per-pattern cycle/bit/beat counters for one tile_io call.

        Metric names and labels are the repo-wide convention documented in
        ``src/repro/obs/README.md``; ``repro.obs.report`` pivots
        ``transfer/cycles`` on the ``pattern`` label to render Fig. 10.
        """
        labels = dict(bench=self.spec.name,
                      tile="x".join(map(str, self.spec.tile_sizes)),
                      dtype=dtype, pattern=mode)
        beats = sum(-(-b // self.model.bus_bits) for b in rbits)
        beats += sum(-(-b // self.model.bus_bits) for b in wbits)
        obs.counter_inc("transfer/cycles", io.total_cycles, **labels)
        obs.counter_inc("burst/beats", beats, **labels)
        for direction, bits, txns in (("read", io.read_bits,
                                       io.read_transactions),
                                      ("write", io.write_bits,
                                       io.write_transactions)):
            obs.counter_inc("transfer/bits", bits, dir=direction, **labels)
            obs.counter_inc("transfer/transactions", txns, dir=direction,
                            **labels)
        sp = obs.tracer().current()
        if sp is not None:
            sp.add_cycles(io.total_cycles)


MODES = ("minimal", "bbox", "mars", "mars_pack", "mars_comp")
