"""PolyBench-faithful stencil definitions and reference executors.

The paper evaluates on PolyBench/C jacobi-1d, jacobi-2d and seidel-2d.  Each
stencil is described here twice:

* a *single-assignment* view used by the polyhedral MARS analysis: an
  iteration space of dimension ``ndim`` where the point ``q`` reads the values
  produced at ``q + r`` for every read offset ``r`` (all offsets are
  lexicographically backward in time),
* a dense numpy reference executor used to generate real data for the
  compression-ratio and transfer-cycle experiments and to validate the tiled
  MARS executor end to end.

Tiling is expressed as an integer *skew* matrix ``S`` plus rectangular tile
sizes in the skewed basis.  ``tile_of(p) = floor(S @ p / tile_sizes)``.  The
diamond tiling of jacobi-1d used in the paper (Fig. 1: a 6x6 tile holding 18
``(t, i)`` points) is ``S = [[1, 1], [1, -1]]`` — a 6x6 box in the skewed
basis contains 18 integer preimages because ``u + v = 2t`` must be even.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

Offset = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """Single-assignment stencil + tiling description."""

    name: str
    ndim: int
    #: read offsets: iteration q reads value produced at q + r for r in reads
    reads: Tuple[Offset, ...]
    #: integer skew matrix (ndim x ndim), unimodular or integer-invertible
    skew: Tuple[Tuple[int, ...], ...]
    #: tile sizes in the skewed basis
    tile_sizes: Tuple[int, ...]

    @property
    def skew_matrix(self) -> np.ndarray:
        return np.asarray(self.skew, dtype=np.int64)

    def tile_of(self, points: np.ndarray) -> np.ndarray:
        """Tile index of each point (points: [n, ndim]) -> [n, ndim]."""
        y = points @ self.skew_matrix.T
        return np.floor_divide(y, np.asarray(self.tile_sizes, dtype=np.int64))

    def with_tile_sizes(self, tile_sizes: Sequence[int]) -> "StencilSpec":
        return dataclasses.replace(self, tile_sizes=tuple(int(t) for t in tile_sizes))


# ---------------------------------------------------------------------------
# Stencil catalogue (PolyBench semantics)
# ---------------------------------------------------------------------------

def jacobi1d_spec(tile_sizes: Sequence[int] = (6, 6)) -> StencilSpec:
    """c[t+1, i] = (c[t, i-1] + c[t, i] + c[t, i+1]) / 3, diamond tiling."""
    return StencilSpec(
        name="jacobi-1d",
        ndim=2,
        reads=((-1, -1), (-1, 0), (-1, 1)),
        skew=((1, 1), (1, -1)),
        tile_sizes=tuple(int(t) for t in tile_sizes),
    )


def jacobi2d_spec(tile_sizes: Sequence[int] = (4, 5, 7)) -> StencilSpec:
    """c[t+1,i,j] = 0.2*(c[t,i,j] + c[t,i±1,j] + c[t,i,j±1]).

    Classic time-skewing ``(t, i + t, j + t)`` makes all dependences
    non-negative so rectangular tiles are legal (Pluto-style).
    """
    return StencilSpec(
        name="jacobi-2d",
        ndim=3,
        reads=(
            (-1, -1, -1),   # (t-1, i,   j)   in skewed coords
            (-1, -2, -1),   # (t-1, i-1, j)
            (-1, 0, -1),    # (t-1, i+1, j)
            (-1, -1, -2),   # (t-1, i,   j-1)
            (-1, -1, 0),    # (t-1, i,   j+1)
        ),
        # reads above are already expressed in the skewed basis, so S = I.
        skew=((1, 0, 0), (0, 1, 0), (0, 0, 1)),
        tile_sizes=tuple(int(t) for t in tile_sizes),
    )


def seidel2d_spec(tile_sizes: Sequence[int] = (4, 10, 10)) -> StencilSpec:
    """In-place 9-point Gauss-Seidel sweep (PolyBench seidel-2d).

    A[i][j] at sweep t reads the *current* sweep's values for (i-1, j-1),
    (i-1, j), (i-1, j+1), (i, j-1) and the *previous* sweep's values for
    (i, j), (i, j+1), (i+1, j-1), (i+1, j), (i+1, j+1).

    Skewing ``(t, u, v) = (t, 2t + i, 3t + 2i + j)`` makes every dependence
    component non-negative, legalising rectangular tiles.  The paper does not
    print its transform; among the legal small skews this one reproduces the
    published Table-1 characteristics exactly (33 input MARS, 13 output MARS,
    10 read bursts, 1 write burst) and is used throughout.  Read offsets below
    are the images of the 9 value-based dependences under the transform.
    """
    # original-space dependences: (dt, di, dj) meaning q reads q + (dt,di,dj)
    orig = [
        (0, -1, -1), (0, -1, 0), (0, -1, 1), (0, 0, -1),
        (-1, 0, 0), (-1, 0, 1), (-1, 1, -1), (-1, 1, 0), (-1, 1, 1),
    ]
    T = np.array([[1, 0, 0], [2, 1, 0], [3, 2, 1]], dtype=np.int64)
    reads = tuple(tuple(int(x) for x in (T @ np.array(d))) for d in orig)
    return StencilSpec(
        name="seidel-2d",
        ndim=3,
        reads=reads,
        skew=((1, 0, 0), (0, 1, 0), (0, 0, 1)),
        tile_sizes=tuple(int(t) for t in tile_sizes),
    )


SPECS: Dict[str, Callable[..., StencilSpec]] = {
    "jacobi-1d": jacobi1d_spec,
    "jacobi-2d": jacobi2d_spec,
    "seidel-2d": seidel2d_spec,
}

#: the config zoo — every (benchmark, tile-size) pair the repo validates
#: against the paper's Table 1.  One source of truth: the table-1 bench,
#: the layout-invariant pass of ``repro.analysis``, and tests all iterate
#: this grid (MARS counts/bursts are tile-size independent; multiple tile
#: sizes per benchmark prove it).
ZOO: Dict[str, Tuple[Tuple[int, ...], ...]] = {
    "jacobi-1d": ((6, 6), (64, 64), (200, 200)),
    "jacobi-2d": ((4, 5, 7), (10, 10, 10)),
    "seidel-2d": ((4, 10, 10),),
}


def zoo_specs() -> Dict[Tuple[str, Tuple[int, ...]], StencilSpec]:
    """(name, tile_sizes) -> built spec, over the whole zoo."""
    return {(name, ts): SPECS[name](ts)
            for name, tiles in ZOO.items() for ts in tiles}


# ---------------------------------------------------------------------------
# Dense reference executors (data generators for compression experiments)
# ---------------------------------------------------------------------------

def jacobi1d_reference(init: np.ndarray, tsteps: int) -> np.ndarray:
    """Return the full (tsteps+1, n) single-assignment value array."""
    n = init.shape[0]
    hist = np.empty((tsteps + 1, n), dtype=np.float64)
    hist[0] = init
    cur = init.astype(np.float64)
    for t in range(tsteps):
        nxt = cur.copy()
        nxt[1:-1] = (cur[:-2] + cur[1:-1] + cur[2:]) / 3.0
        hist[t + 1] = nxt
        cur = nxt
    return hist


def jacobi2d_reference(init: np.ndarray, tsteps: int) -> np.ndarray:
    """Full (tsteps+1, n, n) history of the 5-point Jacobi iteration."""
    hist = np.empty((tsteps + 1,) + init.shape, dtype=np.float64)
    hist[0] = init
    cur = init.astype(np.float64)
    for t in range(tsteps):
        nxt = cur.copy()
        nxt[1:-1, 1:-1] = 0.2 * (
            cur[1:-1, 1:-1] + cur[:-2, 1:-1] + cur[2:, 1:-1]
            + cur[1:-1, :-2] + cur[1:-1, 2:]
        )
        hist[t + 1] = nxt
        cur = nxt
    return hist


def seidel2d_reference(init: np.ndarray, tsteps: int) -> np.ndarray:
    """Full (tsteps+1, n, n) history of in-place 9-point Gauss-Seidel."""
    hist = np.empty((tsteps + 1,) + init.shape, dtype=np.float64)
    hist[0] = init
    cur = init.astype(np.float64).copy()
    n = cur.shape[0]
    for t in range(tsteps):
        for i in range(1, n - 1):
            for j in range(1, n - 1):
                cur[i, j] = (
                    cur[i - 1, j - 1] + cur[i - 1, j] + cur[i - 1, j + 1]
                    + cur[i, j - 1] + cur[i, j] + cur[i, j + 1]
                    + cur[i + 1, j - 1] + cur[i + 1, j] + cur[i + 1, j + 1]
                ) / 9.0
        hist[t + 1] = cur.copy()
    return hist


REFERENCES = {
    "jacobi-1d": jacobi1d_reference,
    "jacobi-2d": jacobi2d_reference,
    "seidel-2d": seidel2d_reference,
}


def stencil_value(name: str, hist: np.ndarray, point: np.ndarray) -> float:
    """Value produced at single-assignment iteration ``point``.

    Conventions (consistent with each spec's read offsets):
      * jacobi kernels: point (t, ...) with t >= 1 produces hist[t] and reads
        hist[t-1] (hist[0] is the initial data, not a computed point);
      * seidel-2d: skewed point (t, u, v) with t >= 0 is sweep t, producing
        hist[t + 1]; its (t-1, .) reads reference hist[t].  The skewed point
        maps back via i = u - 2t, j = v - 3t - 2i.
    """
    if name == "jacobi-1d":
        t, i = point
        return hist[t, i]
    if name == "jacobi-2d":
        t, u, v = point
        return hist[t, u - t, v - t]
    if name == "seidel-2d":
        t, u, v = point
        i = u - 2 * t
        j = v - 3 * t - 2 * i
        return hist[t + 1, i, j]
    raise KeyError(name)


def stencil_values(name: str, hist: np.ndarray, points: np.ndarray) -> np.ndarray:
    """Vectorized :func:`stencil_value` over ``points`` ([n, ndim])."""
    pts = np.asarray(points, dtype=np.int64)
    if name == "jacobi-1d":
        return hist[pts[:, 0], pts[:, 1]]
    if name == "jacobi-2d":
        t = pts[:, 0]
        return hist[t, pts[:, 1] - t, pts[:, 2] - t]
    if name == "seidel-2d":
        t, u, v = pts[:, 0], pts[:, 1], pts[:, 2]
        i = u - 2 * t
        j = v - 3 * t - 2 * i
        return hist[t + 1, i, j]
    raise KeyError(name)
