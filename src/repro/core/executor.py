"""End-to-end tiled MARS executor (software model of the §4 accelerator).

Simulates the full paper pipeline for jacobi-1d with diamond tiling:

  read MARS (seek via markers, decompress) -> dispatch -> execute tile ->
  collect -> compress+pack -> write MARS

Global memory holds one `CompressedStream` per produced tile (the paper's
contiguous per-tile allocation, §3.2.1).  Full tiles run through the MARS
path; partial tiles (touching the space/time boundary) run on the "host"
(§4.3) using the dense reference allocation.  The executor's final state is
compared against the dense reference — this is the correctness proof of the
whole layout + codec machinery, standing in for the paper's on-board runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.obs import instrument as obs

from . import compression as comp
from .layout import LayoutResult, layout_for_analysis
from .mars import MarsAnalysis, analyze
from .stencil import StencilSpec, jacobi1d_reference

TileId = Tuple[int, ...]


@dataclasses.dataclass
class ExecStats:
    full_tiles: int = 0
    host_tiles: int = 0
    compressed_bits: int = 0
    uncompressed_bits: int = 0
    mars_read: int = 0
    mars_written: int = 0

    def publish(self, **labels) -> None:
        """Push every field into the obs registry as ``exec/<field>``.

        Counters accumulate across publishes, so call once per run (the
        executor does, at the end of :meth:`Jacobi1dMarsExecutor.run`).
        No-op while obs is disabled.
        """
        for f in dataclasses.fields(self):
            obs.counter_inc(f"exec/{f.name}", getattr(self, f.name), **labels)


class Jacobi1dMarsExecutor:
    """Tile-by-tile jacobi-1d using the MARS layout + codec."""

    def __init__(self, spec: StencilSpec, n: int, tsteps: int,
                 dtype: str = "fixed24", record: bool = False):
        assert spec.name == "jacobi-1d"
        self.spec = spec
        self.n, self.tsteps = n, tsteps
        self.dtype = dtype
        self.nbits = comp.DATA_TYPES[dtype][0]
        self.analysis: MarsAnalysis = analyze(spec)
        self.layout: LayoutResult = layout_for_analysis(self.analysis)
        #: MARS id -> slot in the layout order (avoids per-read .index())
        self._slot: Dict[int, int] = {m: k for k, m
                                      in enumerate(self.layout.order)}
        # global memory: tile id -> compressed stream of its out-MARS
        self.memory: Dict[TileId, comp.CompressedStream] = {}
        self.stats = ExecStats()
        self.record = record
        #: (t, i) -> value computed by a FULL tile through the MARS path
        self.full_tile_values: Dict[Tuple[int, int], float] = {}

    # -- geometry -----------------------------------------------------------
    def _tiles_covering(self) -> List[TileId]:
        """All tile indices intersecting the computed domain, wavefront order."""
        S = self.spec.skew_matrix
        ts = np.asarray(self.spec.tile_sizes)
        corners = []
        for t in (1, self.tsteps):
            for i in (0, self.n - 1):
                corners.append(S @ np.array([t, i]))
        corners = np.array(corners)
        lo = np.floor_divide(corners.min(axis=0), ts) - 1
        hi = np.floor_divide(corners.max(axis=0), ts) + 1
        tiles = [(int(a), int(b))
                 for a in range(lo[0], hi[0] + 1)
                 for b in range(lo[1], hi[1] + 1)]
        # dependence-legal order: skewed coordinates are lexicographically
        # non-decreasing along dependences, so sort by (a + b, a) wavefront.
        tiles.sort(key=lambda c: (c[0] + c[1], c[0]))
        return tiles

    def _tile_points(self, tile: TileId) -> np.ndarray:
        from .mars import _enumerate_tile_points
        pts = _enumerate_tile_points(self.spec, np.asarray(tile))
        in_dom = ((pts[:, 0] >= 1) & (pts[:, 0] <= self.tsteps)
                  & (pts[:, 1] >= 0) & (pts[:, 1] <= self.n - 1))
        return pts[in_dom]

    def _is_full(self, tile: TileId, pts: np.ndarray) -> bool:
        if pts.shape[0] != self.analysis.tile_points:
            return False
        # all stencil reads must be interior (no boundary clamping inside)
        return bool(np.all(pts[:, 1] >= 1) and np.all(pts[:, 1] <= self.n - 2)
                    and np.all(pts[:, 0] >= 1))

    # -- value plumbing ------------------------------------------------------
    def _encode(self, vals: np.ndarray) -> np.ndarray:
        if self.dtype.startswith("fixed"):
            return comp.quantize_fixed(vals, self.nbits)
        words, _ = comp.float_bits(vals, self.dtype)
        return words

    def _decode(self, words: np.ndarray) -> np.ndarray:
        if self.dtype.startswith("fixed"):
            return comp.dequantize_fixed(words, self.nbits)
        if self.dtype == "float":
            return words.astype(np.uint32).view(np.float32).astype(np.float64)
        return words.view(np.float64)

    def _read_inputs(self, tile: TileId) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Fetch all consumed MARS of this tile, decompressing via markers.

        Returns (points, values) array pairs — no per-point dict fills.
        """
        out: List[Tuple[np.ndarray, np.ndarray]] = []
        c0 = np.asarray(tile)
        for producer_off, mars_ids in self.analysis.consumed.items():
            producer = tuple(int(x) for x in (c0 + np.asarray(producer_off)))
            stream = self.memory.get(producer)
            if stream is None:
                continue  # producer outside computed domain
            pa = analyze(self.spec, producer)
            for mid in mars_ids:
                words = comp.decompress_mars(stream, self._slot[mid])
                out.append((pa.out_mars[mid].points, self._decode(words)))
                self.stats.mars_read += 1
        return out

    def _write_output(self, tile: TileId, pa: MarsAnalysis,
                      getval: Callable[[np.ndarray], np.ndarray]) -> None:
        mars_vals: List[np.ndarray] = []
        for mid in self.layout.order:
            mars_vals.append(self._encode(getval(pa.out_mars[mid].points)))
        stream = comp.compress_mars_stream(mars_vals, self.nbits)
        self.memory[tile] = stream
        self.stats.mars_written += len(mars_vals)
        self.stats.compressed_bits += stream.compressed_bits
        self.stats.uncompressed_bits += stream.uncompressed_bits(
            padded_to=comp.DATA_TYPES[self.dtype][1])

    # -- execution -----------------------------------------------------------
    def run(self, init: np.ndarray) -> np.ndarray:
        """Execute all tiles; return final state, and validate against ref."""
        with obs.span("executor/run", bench=self.spec.name, n=self.n,
                      tsteps=self.tsteps, dtype=self.dtype):
            return self._run(init)

    def _run(self, init: np.ndarray) -> np.ndarray:
        assert init.shape[0] == self.n
        hist = jacobi1d_reference(init, self.tsteps)  # host-side truth for
        # partial tiles (§4.3) and boundary conditions
        final = np.array(hist[self.tsteps])

        for tile in self._tiles_covering():
            pts = self._tile_points(tile)
            if pts.shape[0] == 0:
                continue
            pa = analyze(self.spec, tile)
            if not self._is_full(tile, pts):
                # host tile: write back MARS from the reference allocation,
                # padding out-of-domain MARS points with zeros — no full
                # tile consumes them (§4.3: "no FPGA tiles need any missing
                # MARS data from partial tiles")
                def host_getval(mpts: np.ndarray) -> np.ndarray:
                    t, i = mpts[:, 0], mpts[:, 1]
                    ok = ((t >= 1) & (t <= self.tsteps)
                          & (i >= 0) & (i <= self.n - 1))
                    vals = np.zeros(mpts.shape[0])
                    vals[ok] = hist[t[ok], i[ok]]
                    return vals

                self._write_output(tile, pa, host_getval)
                self.stats.host_tiles += 1
                continue

            # full tile: dense wavefront buffer over the tile's (t, i)
            # window plus a one-cell halo; rows execute in ascending t,
            # each as one vectorized stencil update (no per-point dicts).
            t0 = int(pts[:, 0].min()) - 1           # buffer row 0 -> t0
            c0 = int(pts[:, 1].min()) - 1           # buffer col 0 -> c0
            n_rows = int(pts[:, 0].max()) - t0 + 1
            n_cols = int(pts[:, 1].max()) - c0 + 2
            buf = np.zeros((n_rows, n_cols))
            filled = np.zeros((n_rows, n_cols), dtype=bool)
            # seed values the stencil may read but no tile produces: the
            # initial state (t == 0) and the never-updated boundary columns
            if t0 == 0:
                buf[0, :] = init[c0:c0 + n_cols]
                filled[0, :] = True
            for col, edge in ((0, c0), (n_cols - 1, c0 + n_cols - 1)):
                if edge == 0 or edge == self.n - 1:
                    buf[:, col] = init[edge]
                    filled[:, col] = True
            # consumed MARS override the seeds (they carry quantized values)
            for ipts, ivals in self._read_inputs(tile):
                r, c = ipts[:, 0] - t0, ipts[:, 1] - c0
                ok = (r >= 0) & (r < n_rows) & (c >= 0) & (c < n_cols)
                buf[r[ok], c[ok]] = ivals[ok]
                filled[r[ok], c[ok]] = True

            order = np.lexsort(pts.T[::-1])  # by (t, i): legal for jacobi
            spts = pts[order]
            row_starts = np.flatnonzero(
                np.r_[True, spts[1:, 0] != spts[:-1, 0]])
            for lo, hi in zip(row_starts, np.r_[row_starts[1:], len(spts)]):
                r = int(spts[lo, 0]) - t0
                c = spts[lo:hi, 1] - c0
                src = filled[r - 1, c - 1] & filled[r - 1, c] & filled[r - 1, c + 1]
                if not src.all():
                    missing = c[np.argmin(src)]
                    raise KeyError((int(spts[lo, 0]) - 1, int(missing + c0)))
                buf[r, c] = (buf[r - 1, c - 1] + buf[r - 1, c]
                             + buf[r - 1, c + 1]) / 3.0
                filled[r, c] = True

            rr, cc = pts[:, 0] - t0, pts[:, 1] - c0
            self._write_output(
                tile, pa, lambda mpts: buf[mpts[:, 0] - t0, mpts[:, 1] - c0])
            self.stats.full_tiles += 1
            if self.record:
                tv = buf[rr, cc]
                self.full_tile_values.update(
                    {(int(t), int(i)): float(v)
                     for (t, i), v in zip(pts, tv)})
            last = pts[:, 0] == self.tsteps
            final[pts[last, 1]] = buf[rr[last], cc[last]]
        self.stats.publish(bench=self.spec.name, dtype=self.dtype)
        return final
