"""TPU-native block codec: delta + bitplane packing (hardware adaptation).

The paper's FPGA compressor emits a *sequential variable-length bit stream*
(one length field + significant bits per word).  TPU vector units cannot
produce data-dependent-length streams efficiently, and XLA requires static
shapes.  The TPU-native equivalent keeps the paper's two bandwidth levers —
delta correlation and leading-bit suppression — but vectorizes them:

* values are grouped into fixed *blocks* (the MARS analogue: atomic,
  irredundant, independently decodable);
* within a block, deltas are taken along the minor axis (the loop-carried
  dependence of the paper's compressor becomes a shifted vector subtract;
  the first element stays raw, like the paper's ``w0``);
* deltas are truncated to ``b`` two's-complement bits and *bitplane-packed*:
  a group of 32 words is transposed into ``b`` 32-bit planes (log-depth
  shift/or network — the VPU analogue of the FPGA's free wire shuffling);
* per-block metadata (bitwidth, scale, first value) plays the role of the
  paper's §4.2.2 markers.

Static-shape contract: the *packing density* 32/b is chosen at trace time
(config or profiling), matching how the gradient-compression collective and
the KV-cache layout use it.  A dynamic per-block ``b`` variant is provided
for host-side use (`encode_varwidth`), where the stream is materialized at
its true size like the paper's hardware.

All functions are pure jnp and serve as the oracle for ``kernels/bitplane``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 32  # words per bitplane group (one 32-bit plane word per bit)


# ---------------------------------------------------------------------------
# Bitplane transpose (static bitwidth b)
# ---------------------------------------------------------------------------

def bitplane_pack(v: jax.Array, b: int) -> jax.Array:
    """Pack int32 values (..., G, 32) into bitplanes (..., G, b) uint32.

    plane[..., g, j] holds bit j of the 32 words of group g (word i -> bit i).
    """
    assert 1 <= b <= 32
    v = v.astype(jnp.uint32)
    j = jnp.arange(b, dtype=jnp.uint32)
    i = jnp.arange(GROUP, dtype=jnp.uint32)
    bits = (v[..., :, None] >> j) & jnp.uint32(1)          # (..., 32, b)
    planes = jnp.sum(bits << i[:, None], axis=-2, dtype=jnp.uint32)
    return planes


def bitplane_unpack(planes: jax.Array, b: int) -> jax.Array:
    """Inverse of bitplane_pack; sign-extends from b bits to int32."""
    planes = planes.astype(jnp.uint32)
    i = jnp.arange(GROUP, dtype=jnp.uint32)
    j = jnp.arange(b, dtype=jnp.uint32)
    bits = (planes[..., None, :] >> i[:, None]) & jnp.uint32(1)   # (...,32,b)
    vals = jnp.sum(bits << j, axis=-1, dtype=jnp.uint32)
    if b < 32:
        h = jnp.uint32(1 << (b - 1))
        vals = (vals ^ h) - h
    return vals.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Delta transform along the minor axis
# ---------------------------------------------------------------------------

def delta_encode(x: jax.Array) -> jax.Array:
    """x[..., k] -> x[..., k] - x[..., k-1]; x[..., 0] kept raw."""
    return jnp.concatenate(
        [x[..., :1], x[..., 1:] - x[..., :-1]], axis=-1)


def delta_decode(d: jax.Array) -> jax.Array:
    return jnp.cumsum(d, axis=-1, dtype=d.dtype)


# ---------------------------------------------------------------------------
# Fixed-width block compressor (gradient / activation path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockCodecConfig:
    bits: int = 8          # packed two's-complement width b
    block: int = 256       # values per block (multiple of GROUP)
    delta: bool = True     # apply delta transform before packing

    @property
    def ratio(self) -> float:
        return 32.0 / self.bits


def _reshape_blocks(x: jax.Array, block: int) -> jax.Array:
    assert x.size % block == 0, (x.shape, block)
    return x.reshape(-1, block)


def quantize(x: jax.Array, bits: int, block: int) -> Tuple[jax.Array, jax.Array]:
    """float32 -> (int32 codes, per-block scale).  Symmetric, saturating."""
    xb = _reshape_blocks(x, block)
    maxval = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.where(maxval > 0, maxval / qmax, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale[..., 0]


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def compress(x: jax.Array, cfg: BlockCodecConfig) -> Tuple[jax.Array, jax.Array]:
    """float32 array -> (packed planes uint32 [n_blocks, block/32, b], scales).

    With delta enabled, deltas of b-bit codes still fit in b+1 bits; we clamp
    codes to (b-1)-bit range before delta so the deltas fit b bits exactly —
    the error-feedback loop in ``optim/grad_compress.py`` absorbs the extra
    quantization like the paper's compressor absorbs its (lossless there,
    lossy-with-feedback here; divergence documented in DESIGN.md).
    """
    qbits = cfg.bits - 1 if cfg.delta else cfg.bits
    q, scale = quantize(x, qbits, cfg.block)
    if cfg.delta:
        q = delta_encode(q)
    g = q.reshape(q.shape[0], cfg.block // GROUP, GROUP)
    planes = bitplane_pack(g, cfg.bits)
    return planes, scale


def decompress(planes: jax.Array, scale: jax.Array,
               cfg: BlockCodecConfig) -> jax.Array:
    q = bitplane_unpack(planes, cfg.bits)
    q = q.reshape(q.shape[0], cfg.block)
    if cfg.delta:
        q = delta_decode(q)
    return dequantize(q, scale)


def compressed_bytes(n_values: int, cfg: BlockCodecConfig) -> int:
    """Wire size: planes + per-block scale (the markers analogue)."""
    n_blocks = n_values // cfg.block
    return n_blocks * (cfg.block // GROUP) * cfg.bits * 4 + n_blocks * 4


# ---------------------------------------------------------------------------
# Host-side variable-width variant (true data-dependent size, like the FPGA)
# ---------------------------------------------------------------------------

def min_bitwidth(q: np.ndarray) -> np.ndarray:
    """Per-block two's-complement width needed for int values [n, block]."""
    q = np.asarray(q, dtype=np.int64)
    mag = np.where(q >= 0, q, -q - 1)
    k = np.zeros_like(mag)
    nz = mag > 0
    k[nz] = np.floor(np.log2(mag[nz])).astype(np.int64) + 1
    return np.maximum(k.max(axis=-1) + 1, 1)  # +1 sign bit


def encode_varwidth(x: np.ndarray, block: int = 256,
                    delta: bool = True) -> Tuple[int, np.ndarray]:
    """True compressed bit count with per-block minimal widths (host side).

    Returns (total_bits, per-block widths).  Used by benchmarks to report the
    achievable (data-dependent) ratio, against which the static-b kernel is a
    conservative envelope.
    """
    xb = np.asarray(x).reshape(-1, block)
    if np.issubdtype(xb.dtype, np.floating):
        xb = xb.astype(np.float32).view(np.int32).astype(np.int64)
    d = np.concatenate([xb[:, :1], np.diff(xb, axis=1)], axis=1) if delta else xb
    widths = min_bitwidth(d)
    meta_bits = 8 + 32  # width byte + raw first word per block
    total = int(np.sum(widths * block) + len(widths) * meta_bits)
    return total, widths
