"""Core of the reproduction: MARS analysis, layout ILP, packing, compression.

Paper: "An Irredundant and Compressed Data Layout to Optimize Bandwidth
Utilization of FPGA Accelerators" (Ferry, Derumigny, Derrien, Rajopadhye).
"""
from . import blockcodec, compression, layout, mars, packing, stencil, transfer
from .blockcodec import BlockCodecConfig
from .layout import LayoutResult, layout_for_analysis, solve_layout
from .mars import Mars, MarsAnalysis, analyze
from .stencil import SPECS, StencilSpec
from .transfer import MODES, TileIOModel, TransferModel

__all__ = [
    "BlockCodecConfig", "LayoutResult", "Mars", "MarsAnalysis", "MODES",
    "SPECS", "StencilSpec", "TileIOModel", "TransferModel", "analyze",
    "blockcodec", "compression", "layout", "layout_for_analysis", "mars",
    "packing", "solve_layout", "stencil", "transfer",
]
