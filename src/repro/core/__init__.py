"""Core of the reproduction: MARS analysis, layout ILP, packing, compression.

Paper: "An Irredundant and Compressed Data Layout to Optimize Bandwidth
Utilization of FPGA Accelerators" (Ferry, Derumigny, Derrien, Rajopadhye).
"""
from . import (blockcodec, compression, executor, layout, mars, packing,
               stencil, transfer)
from .blockcodec import BlockCodecConfig
from .executor import ExecStats, Jacobi1dMarsExecutor
from .layout import LayoutResult, layout_for_analysis, solve_layout
from .mars import Mars, MarsAnalysis, analyze
from .stencil import SPECS, StencilSpec
from .transfer import MODES, TileIO, TileIOModel, TransferModel

__all__ = [
    "BlockCodecConfig", "ExecStats", "Jacobi1dMarsExecutor", "LayoutResult",
    "Mars", "MarsAnalysis", "MODES", "SPECS", "StencilSpec", "TileIO",
    "TileIOModel", "TransferModel", "analyze", "blockcodec", "compression",
    "executor", "layout", "layout_for_analysis", "mars", "packing",
    "solve_layout", "stencil", "transfer",
]
