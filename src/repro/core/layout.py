"""MARS outer-layout optimization (paper §3.2, Algorithm 1).

The paper formulates the layout as an ILP over successor variables
``delta_{i,j}`` (MARS i immediately precedes MARS j) and permutation
variables ``gamma_i``, maximizing the number of *contiguities*
``sum_p sum_{i != j} a_{p,i,j} delta_{i,j}`` where ``a_{p,i,j} = 1`` iff
consumer tile p consumes both MARS i and j.  The constraints make
``delta`` a Hamiltonian path, so the problem is exactly *maximum-weight
Hamiltonian path* with symmetric edge weights

    w(i, j) = #{ p : p consumes both i and j }.

The paper solves it with Gurobi; no ILP solver ships in this container, so we
solve the identical optimization with

* an exact Held-Karp dynamic program (optimal) for N <= ``EXACT_LIMIT``,
* greedy edge-matching + 2-opt refinement beyond that.

For every benchmark in the paper N <= 13, so the published burst counts are
reproduced by the exact path.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

EXACT_LIMIT = 16


@dataclasses.dataclass(frozen=True)
class LayoutResult:
    order: Tuple[int, ...]          # gamma-ordered list of MARS indices
    contiguities: int               # objective value
    read_bursts: int                # resulting coalesced read transactions
    write_bursts: int               # always 1: tile output block is contiguous
    exact: bool                     # True if solved to optimality
    solve_time_s: float


def _edge_weights(n: int, consumed_sets: Sequence[Iterable[int]]) -> np.ndarray:
    w = np.zeros((n, n), dtype=np.int64)
    for s in consumed_sets:
        idx = sorted(set(s))
        for a, b in itertools.combinations(idx, 2):
            w[a, b] += 1
            w[b, a] += 1
    return w


def count_bursts(order: Sequence[int], consumed_sets: Sequence[Iterable[int]]) -> int:
    """Read transactions: one per maximal run of consumed MARS in the layout."""
    pos = {m: k for k, m in enumerate(order)}
    total = 0
    for s in consumed_sets:
        ks = sorted(pos[m] for m in set(s))
        runs = 1 + sum(1 for a, b in zip(ks, ks[1:]) if b != a + 1)
        total += runs if ks else 0
    return total


def _objective(order: Sequence[int], w: np.ndarray) -> int:
    return int(sum(w[a, b] for a, b in zip(order, order[1:])))


def _held_karp(w: np.ndarray) -> Tuple[List[int], int]:
    """Optimal max-weight Hamiltonian path, O(2^n * n^2)."""
    n = w.shape[0]
    NEG = -(1 << 60)
    size = 1 << n
    dp = np.full((size, n), NEG, dtype=np.int64)
    parent = np.full((size, n), -1, dtype=np.int32)
    for v in range(n):
        dp[1 << v, v] = 0
    for mask in range(size):
        row = dp[mask]
        for last in range(n):
            cur = row[last]
            if cur == NEG:
                continue
            rem = (~mask) & (size - 1)
            v = rem
            while v:
                nxt = (v & -v).bit_length() - 1
                v &= v - 1
                nm = mask | (1 << nxt)
                cand = cur + w[last, nxt]
                if cand > dp[nm, nxt]:
                    dp[nm, nxt] = cand
                    parent[nm, nxt] = last
    full = size - 1
    last = int(np.argmax(dp[full]))
    best = int(dp[full, last])
    path = [last]
    mask = full
    while parent[mask, path[-1]] >= 0:
        prev = int(parent[mask, path[-1]])
        mask ^= 1 << path[-1]
        path.append(prev)
    path.reverse()
    return path, best


def _greedy_2opt(w: np.ndarray, iters: int = 200) -> Tuple[List[int], int]:
    n = w.shape[0]
    # greedy: repeatedly join the heaviest edge between path endpoints
    order = list(range(n))
    rng = np.random.default_rng(0)
    best_order = order[:]
    best = _objective(order, w)
    for _ in range(iters):
        improved = False
        for a in range(n - 1):
            for b in range(a + 1, n):
                cand = best_order[:a] + best_order[a:b + 1][::-1] + best_order[b + 1:]
                obj = _objective(cand, w)
                if obj > best:
                    best_order, best = cand, obj
                    improved = True
        if not improved:
            perm = list(rng.permutation(n))
            obj = _objective(perm, w)
            if obj > best:
                best_order, best = perm, obj
    return best_order, best


def solve_layout(n_mars: int,
                 consumed_sets: Sequence[Iterable[int]]) -> LayoutResult:
    """Order a producer tile's output MARS to maximize read coalescing.

    Args:
      n_mars: number of output MARS of the tile.
      consumed_sets: for each consumer tile, the indices of the MARS it
        consumes (paper constant ``a_{p,i,j}`` = both i and j in a set).
    """
    t0 = time.perf_counter()
    if n_mars == 0:
        return LayoutResult((), 0, 0, 0, True, 0.0)
    w = _edge_weights(n_mars, consumed_sets)
    if n_mars <= EXACT_LIMIT:
        order, obj = _held_karp(w)
        exact = True
    else:
        order, obj = _greedy_2opt(w)
        exact = False
    dt = time.perf_counter() - t0
    return LayoutResult(
        order=tuple(order),
        contiguities=obj,
        read_bursts=count_bursts(order, consumed_sets),
        write_bursts=1,
        exact=exact,
        solve_time_s=dt,
    )


def brute_force_layout(n_mars: int,
                       consumed_sets: Sequence[Iterable[int]]) -> LayoutResult:
    """Exhaustive reference (tests only, n <= 8)."""
    w = _edge_weights(n_mars, consumed_sets)
    best, best_order = -1, None
    for perm in itertools.permutations(range(n_mars)):
        obj = _objective(perm, w)
        if obj > best:
            best, best_order = obj, perm
    return LayoutResult(best_order, best, count_bursts(best_order, consumed_sets),
                        1, True, 0.0)


def layout_for_analysis(analysis) -> LayoutResult:
    """Apply Algorithm 1 to a MarsAnalysis (consumer sets by uniformity).

    Tile T's output MARS are consumed by tiles at offsets ``-d`` for every
    producer offset ``d`` in the analysis, consuming exactly the same index
    set (translation invariance of full tiles).
    """
    consumed_sets = list(analysis.consumed.values())
    return solve_layout(analysis.n_out, consumed_sets)
