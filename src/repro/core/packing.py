"""Padding-vs-packing accounting (paper §2.4, Fig. 5 / Fig. 11).

Custom-bitwidth words (e.g. 17-bit) must normally be padded to the bus
alignment for random access; contiguous MARS accesses allow *packing* them
back to back at the bit level.  These helpers compute the exact transferred
bit counts for both conventions, and the two compression ratios reported in
Fig. 11:

* ``true ratio``      = nbits * count / compressed_bits  (savings from the
  codec alone),
* ``ratio with padding`` = padded_bits * count / compressed_bits  (what the
  accelerator actually saves, because the uncompressed baseline must pad).
"""
from __future__ import annotations

import dataclasses

from .compression import DATA_TYPES


def padded_width(nbits: int) -> int:
    """Aligned storage width for an nbits word on a byte-addressable bus."""
    for w in (8, 16, 32, 64, 128):
        if nbits <= w:
            return w
    raise ValueError(f"unsupported width {nbits}")


def padded_bits(count: int, nbits: int) -> int:
    return count * padded_width(nbits)


def packed_bits(count: int, nbits: int) -> int:
    return count * nbits


@dataclasses.dataclass(frozen=True)
class Ratios:
    true_ratio: float
    ratio_with_padding: float


def compression_ratios(count: int, nbits: int, compressed_bits: int) -> Ratios:
    if compressed_bits <= 0:
        raise ValueError("empty stream")
    return Ratios(
        true_ratio=packed_bits(count, nbits) / compressed_bits,
        ratio_with_padding=padded_bits(count, nbits) / compressed_bits,
    )


def dtype_widths(dtype: str) -> tuple[int, int]:
    """(nbits, padded bits) for a paper data-type name."""
    nbits, padded = DATA_TYPES[dtype]
    return nbits, padded
