"""Faithful runtime differential compression (paper §2.5) + markers (§4.2.2).

Encodes a sequence of N-bit words ``w0 w1 ... wn``:

* ``w0`` raw (N bits);
* for each subsequent word, ``d = w_i - w_{i-1}`` (two's complement, N bits),
  ``k`` = number of significant bits of ``d`` — ``k = bitlen(d)`` when
  ``d >= 0`` and ``k = bitlen(-d - 1)`` when ``d < 0`` (count after stripping
  leading zeros / leading ones respectively).  Emit a length field ``k`` in
  ``F = floor(1 + log2(N))`` bits, the sign bit, then the ``k - 1`` low bits
  of ``d`` (the top significant bit is implicit: 1 for positives, 0 for
  negatives).  ``d = 0`` costs F + 1 bits; ``d = -1`` likewise (k = 0).

Decoding: ``d = 2^(k-1) + low`` (sign 0, k > 0), ``d = low - 2^k`` (sign 1),
``d = 0`` / ``-1`` for k = 0.

This is a bit-exact software model of the paper's FPGA compressor (II = 1
pipelined there; here, a host-side reference).  ``CompressedStream`` also
maintains the *markers* of §4.2.2: for each MARS boundary a coarse position
(aligned bus words) + fine position (bit within the word), allowing a
consumer to seek to and decode exactly one MARS — the delta chain restarts at
every MARS so blocks stay atomic.

Floating-point data is compressed on its raw bit pattern (neighbouring values
share exponent/high-mantissa bits), exactly as the paper's hardware would.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.obs import instrument as obs


def length_field_bits(nbits: int) -> int:
    return int(math.floor(1 + math.log2(nbits)))


# ---------------------------------------------------------------------------
# Bit-level reader / writer
# ---------------------------------------------------------------------------

class BitWriter:
    __slots__ = ("_acc", "_nbits")

    def __init__(self) -> None:
        self._acc = 0
        self._nbits = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        mask = (1 << nbits) - 1
        self._acc |= (value & mask) << self._nbits
        self._nbits += nbits

    @property
    def bit_length(self) -> int:
        return self._nbits

    def to_words(self, word_bits: int = 32) -> np.ndarray:
        n_words = (self._nbits + word_bits - 1) // word_bits
        out = np.zeros(n_words, dtype=np.uint64)
        acc = self._acc
        mask = (1 << word_bits) - 1
        for k in range(n_words):
            out[k] = acc & mask
            acc >>= word_bits
        return out


class BitReader:
    __slots__ = ("_acc", "_pos", "_len")

    def __init__(self, words: np.ndarray, total_bits: int, word_bits: int = 32):
        acc = 0
        for k in range(len(words) - 1, -1, -1):
            acc = (acc << word_bits) | int(words[k])
        self._acc = acc
        self._pos = 0
        self._len = total_bits

    def seek(self, bit: int) -> None:
        self._pos = bit

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        if self._pos + nbits > self._len:
            raise EOFError("read past end of compressed stream")
        v = (self._acc >> self._pos) & ((1 << nbits) - 1)
        self._pos += nbits
        return v


# ---------------------------------------------------------------------------
# Word codec
# ---------------------------------------------------------------------------

def _significant_len(d: int) -> int:
    return (d if d >= 0 else -d - 1).bit_length()


def compress_words(words: Sequence[int], nbits: int, writer: BitWriter) -> None:
    """Append the compressed encoding of ``words`` to ``writer``."""
    F = length_field_bits(nbits)
    mask = (1 << nbits) - 1
    half = 1 << (nbits - 1)
    prev = None
    for w in words:
        w = int(w) & mask
        if prev is None:
            writer.write(w, nbits)
        else:
            d = (w - prev) & mask
            if d >= half:
                d -= 1 << nbits  # signed delta
            k = _significant_len(d)
            writer.write(k, F)
            writer.write(0 if d >= 0 else 1, 1)
            if k > 0:
                low = (d if d >= 0 else d + (1 << k)) & ((1 << (k - 1)) - 1)
                writer.write(low, k - 1)
        prev = w


def decompress_words(reader: BitReader, count: int, nbits: int) -> np.ndarray:
    F = length_field_bits(nbits)
    mask = (1 << nbits) - 1
    out = np.zeros(count, dtype=np.uint64)
    prev = None
    for i in range(count):
        if prev is None:
            prev = reader.read(nbits)
        else:
            k = reader.read(F)
            sign = reader.read(1)
            if k == 0:
                d = 0 if sign == 0 else -1
            else:
                low = reader.read(k - 1)
                d = ((1 << (k - 1)) + low) if sign == 0 else (low - (1 << k))
            prev = (prev + d) & mask
        out[i] = prev
    return out


def compressed_cost_bits(words: np.ndarray, nbits: int) -> int:
    """Vectorized size (bits) of the compressed encoding — no stream built.

    Used by the transfer-cycle experiments where only sizes matter (the paper
    measures cycles, i.e. sizes / bus width).
    """
    F = length_field_bits(nbits)
    w = np.asarray(words, dtype=np.uint64) & np.uint64((1 << nbits) - 1)
    if w.size == 0:
        return 0
    if w.size == 1:
        return nbits
    if nbits == 64:
        # uint64 subtraction wraps mod 2^64; reinterpret as signed delta
        d = (w[1:] - w[:-1]).view(np.int64)
    else:
        d = (w[1:].astype(np.int64) - w[:-1].astype(np.int64))
        # wrap to signed nbits range
        span = np.int64(1) << np.int64(nbits)
        d = ((d + span // 2) % span) - span // 2
    with np.errstate(over="ignore"):
        mag = np.where(d >= 0, d, -d - 1).astype(np.uint64)
    # bit length via float exponent: exact because mag < 2^63 and frexp is
    # exact for integers below 2^53; for nbits > 52 fall back to object loop
    if nbits <= 52:
        k = np.where(mag == 0, 0, np.floor(np.log2(np.maximum(mag, 1))).astype(np.int64) + 1)
    else:
        k = np.array([int(int(m).bit_length()) for m in mag], dtype=np.int64)
    per_word = F + 1 + np.maximum(k - 1, 0)
    return int(nbits + per_word.sum())


# ---------------------------------------------------------------------------
# MARS stream with markers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Marker:
    """Position of a compressed MARS (§4.2.2): coarse word + fine bit."""
    coarse: int   # aligned bus-word index
    fine: int     # bit offset within the bus word


@dataclasses.dataclass
class CompressedStream:
    """Packed, compressed sequence of MARS with seek metadata."""
    words: np.ndarray            # uint64-held bus words
    total_bits: int
    bus_bits: int
    nbits: int                   # uncompressed word width
    markers: List[Marker]        # one per MARS, in layout order
    counts: List[int]            # uncompressed word count per MARS

    @property
    def compressed_bits(self) -> int:
        return self.total_bits

    def uncompressed_bits(self, padded_to: int | None = None) -> int:
        width = padded_to if padded_to is not None else self.nbits
        return width * sum(self.counts)


def compress_mars_stream(mars_data: Sequence[np.ndarray], nbits: int,
                         bus_bits: int = 64) -> CompressedStream:
    """Compress+pack MARS back to back; record markers at each boundary.

    The delta chain restarts at each MARS so any single MARS is independently
    decodable (atomicity), matching §4.2: "not all MARS from a given tile are
    decompressed, we need to be able to seek at the start of a particular
    MARS".
    """
    writer = BitWriter()
    markers: List[Marker] = []
    counts: List[int] = []
    record = obs.enabled()
    for arr in mars_data:
        markers.append(Marker(writer.bit_length // bus_bits,
                              writer.bit_length % bus_bits))
        flat = np.asarray(arr).reshape(-1)
        counts.append(flat.size)
        before = writer.bit_length
        compress_words(flat, nbits, writer)
        if record:
            # per-MARS compressed vs uncompressed (packed) bit histograms:
            # the Fig. 11 distribution, one observation per MARS
            obs.hist_observe("compression/mars_bits",
                             writer.bit_length - before,
                             kind="compressed", nbits=nbits)
            obs.hist_observe("compression/mars_bits", flat.size * nbits,
                             kind="uncompressed", nbits=nbits)
    if record:
        obs.counter_inc("compression/markers", len(markers), nbits=nbits)
        if writer.bit_length > 0:
            obs.hist_observe(
                "compression/ratio",
                nbits * sum(counts) / writer.bit_length, nbits=nbits)
    return CompressedStream(
        words=writer.to_words(32),
        total_bits=writer.bit_length,
        bus_bits=bus_bits,
        nbits=nbits,
        markers=markers,
        counts=counts,
    )


def decompress_mars(stream: CompressedStream, index: int) -> np.ndarray:
    """Seek (via marker) and decode exactly one MARS."""
    reader = BitReader(stream.words, stream.total_bits, 32)
    m = stream.markers[index]
    reader.seek(m.coarse * stream.bus_bits + m.fine)
    return decompress_words(reader, stream.counts[index], stream.nbits)


# ---------------------------------------------------------------------------
# Fixed-point helpers (paper data types: 12/18/24/28-bit fixed, float, double)
# ---------------------------------------------------------------------------

def quantize_fixed(x: np.ndarray, nbits: int, frac_bits: int | None = None) -> np.ndarray:
    """Real -> two's-complement fixed point, returned as unsigned words."""
    if frac_bits is None:
        frac_bits = nbits - 2
    scaled = np.round(np.asarray(x, dtype=np.float64) * (1 << frac_bits)).astype(np.int64)
    lo, hi = -(1 << (nbits - 1)), (1 << (nbits - 1)) - 1
    scaled = np.clip(scaled, lo, hi)
    return (scaled & ((1 << nbits) - 1)).astype(np.uint64)


def dequantize_fixed(w: np.ndarray, nbits: int, frac_bits: int | None = None) -> np.ndarray:
    if frac_bits is None:
        frac_bits = nbits - 2
    w = np.asarray(w, dtype=np.uint64).astype(np.int64)
    half = np.int64(1 << (nbits - 1))
    signed = np.where(w >= half, w - (np.int64(1) << np.int64(nbits)), w)
    return signed.astype(np.float64) / (1 << frac_bits)


def float_bits(x: np.ndarray, dtype: str) -> Tuple[np.ndarray, int]:
    """Raw bit patterns of float32/float64 data + word width."""
    if dtype == "float":
        return np.asarray(x, dtype=np.float32).view(np.uint32).astype(np.uint64), 32
    if dtype == "double":
        return np.asarray(x, dtype=np.float64).view(np.uint64), 64
    raise KeyError(dtype)


DATA_TYPES = {
    # name -> (nbits, padded storage bits on a 32/64-bit aligned bus)
    "fixed12": (12, 16),
    "fixed18": (18, 32),
    "fixed24": (24, 32),
    "fixed28": (28, 32),
    "float": (32, 32),
    "double": (64, 64),
}


def words_for(data: np.ndarray, dtype: str) -> Tuple[np.ndarray, int]:
    """Convert real-valued data to codec words for the named paper dtype."""
    if dtype.startswith("fixed"):
        nbits = DATA_TYPES[dtype][0]
        return quantize_fixed(data, nbits), nbits
    return float_bits(data, dtype)
