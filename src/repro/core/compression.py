"""Faithful runtime differential compression (paper §2.5) + markers (§4.2.2).

Encodes a sequence of N-bit words ``w0 w1 ... wn``:

* ``w0`` raw (N bits);
* for each subsequent word, ``d = w_i - w_{i-1}`` (two's complement, N bits),
  ``k`` = number of significant bits of ``d`` — ``k = bitlen(d)`` when
  ``d >= 0`` and ``k = bitlen(-d - 1)`` when ``d < 0`` (count after stripping
  leading zeros / leading ones respectively).  Emit a length field ``k`` in
  ``F = floor(1 + log2(N))`` bits, the sign bit, then the ``k - 1`` low bits
  of ``d`` (the top significant bit is implicit: 1 for positives, 0 for
  negatives).  ``d = 0`` costs F + 1 bits; ``d = -1`` likewise (k = 0).

Decoding: ``d = 2^(k-1) + low`` (sign 0, k > 0), ``d = low - 2^k`` (sign 1),
``d = 0`` / ``-1`` for k = 0.

This is a bit-exact software model of the paper's FPGA compressor (II = 1
pipelined there; here, a host-side reference).  ``CompressedStream`` also
maintains the *markers* of §4.2.2: for each MARS boundary a coarse position
(aligned bus words) + fine position (bit within the word), allowing a
consumer to seek to and decode exactly one MARS — the delta chain restarts at
every MARS so blocks stay atomic.

Floating-point data is compressed on its raw bit pattern (neighbouring values
share exponent/high-mantissa bits), exactly as the paper's hardware would.

Two implementations of the same bit format live here:

* the **fast path** (``BitWriter``/``BitReader`` + ``compress_words`` /
  ``decompress_words``): chunked uint64 word buffers and vectorized numpy
  delta/length/bit-packing — O(n) in stream length, no Python bignum;
* the **reference path** (``ReferenceBitWriter``/``ReferenceBitReader`` +
  ``compress_words_ref``/``decompress_words_ref``): the original per-word,
  single-bignum model, kept as the equivalence oracle — property tests and
  ``benchmarks/bench_codec.py`` assert the two produce bit-identical streams.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.obs import instrument as obs

_M64 = (1 << 64) - 1
_U64 = np.uint64


def length_field_bits(nbits: int) -> int:
    return int(math.floor(1 + math.log2(nbits)))


# ---------------------------------------------------------------------------
# Bit-level reader / writer (fast path: chunked uint64 buffers, no bignum)
# ---------------------------------------------------------------------------

class BitWriter:
    """Append-only bit stream held as 64-bit chunks (LSB-first bit order)."""

    __slots__ = ("_chunks", "_nbits")

    def __init__(self) -> None:
        self._chunks = np.zeros(16, dtype=np.uint64)
        self._nbits = 0

    def _reserve(self, nbits: int) -> None:
        need = (self._nbits + nbits) // 64 + 2
        if need > len(self._chunks):
            grown = np.zeros(max(need, 2 * len(self._chunks)), dtype=np.uint64)
            grown[: len(self._chunks)] = self._chunks
            self._chunks = grown

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        self._reserve(nbits)
        v = int(value) & ((1 << nbits) - 1)
        w, off = divmod(self._nbits, 64)
        self._chunks[w] |= _U64((v << off) & _M64)
        if off + nbits > 64:
            self._chunks[w + 1] = _U64(v >> (64 - off))
        self._nbits += nbits

    def write_many(self, values: np.ndarray, widths: np.ndarray) -> None:
        """Pack many variable-width fields (each <= 64 bits) at once.

        Fields land at consecutive bit offsets; a field spans at most two
        64-bit chunks, so the whole batch is two masked scatters.
        """
        values = np.asarray(values, dtype=np.uint64)
        widths = np.asarray(widths, dtype=np.int64)
        total = int(widths.sum())
        if total == 0:
            return
        self._reserve(total)
        offs = self._nbits + np.concatenate(
            ([0], np.cumsum(widths[:-1], dtype=np.int64)))
        w = offs >> 6
        sh = (offs & 63).astype(np.uint64)
        width_u = widths.astype(np.uint64)
        mask = np.where(widths >= 64, _U64(_M64),
                        (_U64(1) << (width_u & _U64(63))) - _U64(1))
        v = values & mask
        lo = v << sh
        hi = np.where(sh > 0, v >> ((_U64(64) - sh) & _U64(63)), _U64(0))
        np.bitwise_or.at(self._chunks, w, lo)
        np.bitwise_or.at(self._chunks, w + 1, hi)
        self._nbits += total

    @property
    def bit_length(self) -> int:
        return self._nbits

    def to_words(self, word_bits: int = 32) -> np.ndarray:
        n_words = (self._nbits + word_bits - 1) // word_bits
        used = (self._nbits + 63) // 64
        chunks = self._chunks[:used]
        if word_bits == 64:
            return chunks[:n_words].copy()
        if 64 % word_bits == 0:
            per = 64 // word_bits
            shifts = (np.arange(per, dtype=np.uint64) * _U64(word_bits))
            mask = _U64((1 << word_bits) - 1)
            split = (chunks[:, None] >> shifts[None, :]) & mask
            return split.reshape(-1)[:n_words].copy()
        reader = BitReader(chunks, self._nbits, 64)
        out = np.zeros(n_words, dtype=np.uint64)
        for k in range(n_words):
            out[k] = reader.read(min(word_bits, self._nbits - k * word_bits))
        return out


def _repack_chunks(words: np.ndarray, total_bits: int,
                   word_bits: int) -> List[int]:
    """word_bits-wide words -> list of 64-bit Python-int chunks (+1 pad)."""
    n_chunks = (total_bits + 63) // 64
    if word_bits == 64:
        out = [int(w) for w in np.asarray(words, dtype=np.uint64)[:n_chunks]]
    elif 64 % word_bits == 0:
        per = 64 // word_bits
        arr = np.asarray(words, dtype=np.uint64)
        mask = _U64((1 << word_bits) - 1)
        pad = (-len(arr)) % per
        if pad:
            arr = np.concatenate([arr, np.zeros(pad, dtype=np.uint64)])
        arr = (arr & mask).reshape(-1, per)
        shifts = (np.arange(per, dtype=np.uint64) * _U64(word_bits))
        merged = np.bitwise_or.reduce(arr << shifts[None, :], axis=1)
        out = [int(c) for c in merged[:n_chunks]]
    else:
        out, cur, fill = [], 0, 0
        mask = (1 << word_bits) - 1
        for w in words:
            cur |= (int(w) & mask) << fill
            fill += word_bits
            while fill >= 64:
                out.append(cur & _M64)
                cur >>= 64
                fill -= 64
        if fill:
            out.append(cur & _M64)
        out = out[:n_chunks]
    out.extend([0] * (n_chunks + 2 - len(out)))
    return out


class BitReader:
    """Bit stream reader over 64-bit chunks (no bignum accumulator)."""

    __slots__ = ("_chunks", "_pos", "_len")

    def __init__(self, words: np.ndarray, total_bits: int, word_bits: int = 32):
        self._chunks = _repack_chunks(words, total_bits, word_bits)
        self._pos = 0
        self._len = total_bits

    def seek(self, bit: int) -> None:
        if not 0 <= bit <= self._len:
            raise ValueError(
                f"seek({bit}) out of bounds for stream of {self._len} bits")
        self._pos = bit

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        if self._pos + nbits > self._len:
            raise EOFError("read past end of compressed stream")
        w, off = divmod(self._pos, 64)
        v = self._chunks[w] >> off
        if off + nbits > 64:
            v |= self._chunks[w + 1] << (64 - off)
        self._pos += nbits
        return v & ((1 << nbits) - 1)


# ---------------------------------------------------------------------------
# Reference bit-level reader / writer (original single-bignum model)
# ---------------------------------------------------------------------------

class ReferenceBitWriter:
    """Original per-write bignum accumulator — equivalence oracle only."""

    __slots__ = ("_acc", "_nbits")

    def __init__(self) -> None:
        self._acc = 0
        self._nbits = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        mask = (1 << nbits) - 1
        self._acc |= (value & mask) << self._nbits
        self._nbits += nbits

    @property
    def bit_length(self) -> int:
        return self._nbits

    def to_words(self, word_bits: int = 32) -> np.ndarray:
        n_words = (self._nbits + word_bits - 1) // word_bits
        out = np.zeros(n_words, dtype=np.uint64)
        acc = self._acc
        mask = (1 << word_bits) - 1
        for k in range(n_words):
            out[k] = acc & mask
            acc >>= word_bits
        return out


class ReferenceBitReader:
    """Original single-bignum reader — equivalence oracle only."""

    __slots__ = ("_acc", "_pos", "_len")

    def __init__(self, words: np.ndarray, total_bits: int, word_bits: int = 32):
        acc = 0
        for k in range(len(words) - 1, -1, -1):
            acc = (acc << word_bits) | int(words[k])
        self._acc = acc
        self._pos = 0
        self._len = total_bits

    def seek(self, bit: int) -> None:
        if not 0 <= bit <= self._len:
            raise ValueError(
                f"seek({bit}) out of bounds for stream of {self._len} bits")
        self._pos = bit

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        if self._pos + nbits > self._len:
            raise EOFError("read past end of compressed stream")
        v = (self._acc >> self._pos) & ((1 << nbits) - 1)
        self._pos += nbits
        return v


# ---------------------------------------------------------------------------
# Word codec
# ---------------------------------------------------------------------------

def _significant_len(d: int) -> int:
    return (d if d >= 0 else -d - 1).bit_length()


def _bit_length_u64(v: np.ndarray) -> np.ndarray:
    """Exact per-element bit length of a uint64 array (binary search)."""
    v = v.copy()
    k = np.zeros(v.shape, dtype=np.uint64)
    for s in (32, 16, 8, 4, 2, 1):
        big = v >= (_U64(1) << _U64(s))
        k[big] += _U64(s)
        v[big] >>= _U64(s)
    return k + (v > 0)


def compress_words(words: Sequence[int], nbits: int, writer) -> None:
    """Append the compressed encoding of ``words`` to ``writer``.

    Vectorized: block-wise delta, significant-length and low-bit extraction
    in numpy, then one ``write_many`` packing all fields into the writer's
    uint64 chunk buffer.  Falls back to the scalar reference loop for
    writers without ``write_many`` (e.g. ``ReferenceBitWriter``).
    """
    if not hasattr(writer, "write_many"):
        compress_words_ref(words, nbits, writer)
        return
    arr = np.asarray(words, dtype=np.uint64).reshape(-1)
    n = arr.size
    if n == 0:
        return
    F = length_field_bits(nbits)
    mask = _U64((1 << nbits) - 1)
    half = _U64(1 << (nbits - 1))
    arr = arr & mask
    if n == 1:
        writer.write(int(arr[0]), nbits)
        return
    d = (arr[1:] - arr[:-1]) & mask          # delta mod 2^nbits
    neg = d >= half                          # signed delta < 0
    mag = np.where(neg, mask - d, d)         # |d| or |d|-1: -d-1 == mask-d
    k = _bit_length_u64(mag)
    low_width = np.maximum(k.astype(np.int64) - 1, 0)
    low_mask = np.where(k > 0, (_U64(1) << ((k - _U64(1)) & _U64(63)))
                        - _U64(1), _U64(0))
    low = d & low_mask                       # d mod 2^(k-1), both signs
    header = k | (neg.astype(np.uint64) << _U64(F))
    vals = np.zeros(2 * n - 1, dtype=np.uint64)
    wids = np.zeros(2 * n - 1, dtype=np.int64)
    vals[0], wids[0] = arr[0], nbits
    vals[1::2], wids[1::2] = header, F + 1
    vals[2::2], wids[2::2] = low, low_width
    writer.write_many(vals, wids)


def compress_words_ref(words: Sequence[int], nbits: int, writer) -> None:
    """Reference per-word encoder (original implementation, oracle only)."""
    F = length_field_bits(nbits)
    mask = (1 << nbits) - 1
    half = 1 << (nbits - 1)
    prev = None
    for w in words:
        w = int(w) & mask
        if prev is None:
            writer.write(w, nbits)
        else:
            d = (w - prev) & mask
            if d >= half:
                d -= 1 << nbits  # signed delta
            k = _significant_len(d)
            writer.write(k, F)
            writer.write(0 if d >= 0 else 1, 1)
            if k > 0:
                low = (d if d >= 0 else d + (1 << k)) & ((1 << (k - 1)) - 1)
                writer.write(low, k - 1)
        prev = w
    return


def decompress_words(reader: BitReader, count: int, nbits: int) -> np.ndarray:
    """Decode ``count`` words; vectorized reconstruction after a field scan.

    The field widths are data-dependent (the length field of word ``i`` sits
    after word ``i-1``'s low bits) so offsets are scanned sequentially —
    O(1) chunk reads per word, no bignum — and the delta chain is then
    rebuilt in one masked ``cumsum``.
    """
    if not isinstance(reader, BitReader):
        return decompress_words_ref(reader, count, nbits)
    out = np.zeros(count, dtype=np.uint64)
    if count == 0:
        return out
    F = length_field_bits(nbits)
    first = reader.read(nbits)
    if count == 1:
        out[0] = first
        return out
    f_mask = (1 << F) - 1
    chunks = reader._chunks
    pos, end = reader._pos, reader._len
    ks = np.zeros(count - 1, dtype=np.int64)
    signs = np.zeros(count - 1, dtype=np.uint64)
    lows = np.zeros(count - 1, dtype=np.uint64)
    for i in range(count - 1):
        if pos + F + 1 > end:
            raise EOFError("read past end of compressed stream")
        w, off = divmod(pos, 64)
        # 128-off valid bits: enough for header + low except when a long
        # low field straddles a third chunk (off > 128 - (F + k))
        window = (chunks[w] >> off) | (chunks[w + 1] << (64 - off))
        k = window & f_mask
        if k >= nbits:
            raise ValueError(
                f"corrupt stream: length field {k} >= word width {nbits}")
        width = F + 1 + (k - 1 if k > 0 else 0)
        if pos + width > end:
            raise EOFError("read past end of compressed stream")
        if k > 1:
            if 128 - off < F + k:
                window |= chunks[w + 2] << (128 - off)
            lows[i] = (window >> (F + 1)) & ((1 << (k - 1)) - 1)
        ks[i] = k
        signs[i] = (window >> F) & 1
        pos += width
    reader._pos = pos
    mask = _U64((1 << nbits) - 1)
    ku = ks.astype(np.uint64)
    pos_d = (_U64(1) << ((ku - _U64(1)) & _U64(63))) + lows   # 2^(k-1) + low
    neg_d = (lows - (_U64(1) << (ku & _U64(63)))) & mask      # low - 2^k
    d = np.where(ks > 0,
                 np.where(signs == 0, pos_d, neg_d),
                 np.where(signs == 0, _U64(0), mask))
    out[0] = first
    out[1:] = (_U64(first) + np.cumsum(d, dtype=np.uint64)) & mask
    return out


def decompress_words_ref(reader, count: int, nbits: int) -> np.ndarray:
    """Reference per-word decoder (original implementation, oracle only)."""
    F = length_field_bits(nbits)
    mask = (1 << nbits) - 1
    out = np.zeros(count, dtype=np.uint64)
    prev = None
    for i in range(count):
        if prev is None:
            prev = reader.read(nbits)
        else:
            k = reader.read(F)
            sign = reader.read(1)
            if k == 0:
                d = 0 if sign == 0 else -1
            else:
                low = reader.read(k - 1)
                d = ((1 << (k - 1)) + low) if sign == 0 else (low - (1 << k))
            prev = (prev + d) & mask
        out[i] = prev
    return out


def compressed_cost_bits(words: np.ndarray, nbits: int) -> int:
    """Vectorized size (bits) of the compressed encoding — no stream built.

    Used by the transfer-cycle experiments where only sizes matter (the paper
    measures cycles, i.e. sizes / bus width).
    """
    F = length_field_bits(nbits)
    w = np.asarray(words, dtype=np.uint64) & np.uint64((1 << nbits) - 1)
    if w.size == 0:
        return 0
    if w.size == 1:
        return nbits
    if nbits == 64:
        # uint64 subtraction wraps mod 2^64; reinterpret as signed delta
        d = (w[1:] - w[:-1]).view(np.int64)
    else:
        d = (w[1:].astype(np.int64) - w[:-1].astype(np.int64))
        # wrap to signed nbits range
        span = np.int64(1) << np.int64(nbits)
        d = ((d + span // 2) % span) - span // 2
    with np.errstate(over="ignore"):
        mag = np.where(d >= 0, d, -d - 1).astype(np.uint64)
    k = _bit_length_u64(mag).astype(np.int64)
    per_word = F + 1 + np.maximum(k - 1, 0)
    return int(nbits + per_word.sum())


# ---------------------------------------------------------------------------
# MARS stream with markers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Marker:
    """Position of a compressed MARS (§4.2.2): coarse word + fine bit."""
    coarse: int   # aligned bus-word index
    fine: int     # bit offset within the bus word


@dataclasses.dataclass
class CompressedStream:
    """Packed, compressed sequence of MARS with seek metadata."""
    words: np.ndarray            # uint64-held bus words
    total_bits: int
    bus_bits: int
    nbits: int                   # uncompressed word width
    markers: List[Marker]        # one per MARS, in layout order
    counts: List[int]            # uncompressed word count per MARS

    @property
    def compressed_bits(self) -> int:
        return self.total_bits

    def uncompressed_bits(self, padded_to: int | None = None) -> int:
        width = padded_to if padded_to is not None else self.nbits
        return width * sum(self.counts)


def compress_mars_stream(mars_data: Sequence[np.ndarray], nbits: int,
                         bus_bits: int = 64) -> CompressedStream:
    """Compress+pack MARS back to back; record markers at each boundary.

    The delta chain restarts at each MARS so any single MARS is independently
    decodable (atomicity), matching §4.2: "not all MARS from a given tile are
    decompressed, we need to be able to seek at the start of a particular
    MARS".
    """
    writer = BitWriter()
    markers: List[Marker] = []
    counts: List[int] = []
    record = obs.enabled()
    for arr in mars_data:
        markers.append(Marker(writer.bit_length // bus_bits,
                              writer.bit_length % bus_bits))
        flat = np.asarray(arr).reshape(-1)
        counts.append(flat.size)
        before = writer.bit_length
        compress_words(flat, nbits, writer)
        if record:
            # per-MARS compressed vs uncompressed (packed) bit histograms:
            # the Fig. 11 distribution, one observation per MARS
            obs.hist_observe("compression/mars_bits",
                             writer.bit_length - before,
                             kind="compressed", nbits=nbits)
            obs.hist_observe("compression/mars_bits", flat.size * nbits,
                             kind="uncompressed", nbits=nbits)
    if record:
        obs.counter_inc("compression/markers", len(markers), nbits=nbits)
        if writer.bit_length > 0:
            obs.hist_observe(
                "compression/ratio",
                nbits * sum(counts) / writer.bit_length, nbits=nbits)
    return CompressedStream(
        words=writer.to_words(32),
        total_bits=writer.bit_length,
        bus_bits=bus_bits,
        nbits=nbits,
        markers=markers,
        counts=counts,
    )


def decompress_mars(stream: CompressedStream, index: int) -> np.ndarray:
    """Seek (via marker) and decode exactly one MARS.

    Corrupt metadata fails loudly: a marker pointing past ``total_bits``
    or a count larger than the remaining stream raises ``ValueError``
    instead of decoding garbage.
    """
    if not 0 <= index < len(stream.markers):
        raise IndexError(
            f"MARS index {index} out of range ({len(stream.markers)} markers)")
    m = stream.markers[index]
    start = m.coarse * stream.bus_bits + m.fine
    if not 0 <= start <= stream.total_bits:
        raise ValueError(
            f"corrupt marker for MARS {index}: bit offset {start} outside "
            f"stream of {stream.total_bits} bits")
    count = stream.counts[index]
    if count < 0:
        raise ValueError(f"corrupt count for MARS {index}: {count}")
    reader = BitReader(stream.words, stream.total_bits, 32)
    reader.seek(start)
    try:
        return decompress_words(reader, count, stream.nbits)
    except (EOFError, ValueError) as e:
        raise ValueError(
            f"corrupt stream decoding MARS {index} "
            f"(count={count}, start bit {start}): {e}") from e


# ---------------------------------------------------------------------------
# Fixed-point helpers (paper data types: 12/18/24/28-bit fixed, float, double)
# ---------------------------------------------------------------------------

def quantize_fixed(x: np.ndarray, nbits: int, frac_bits: int | None = None) -> np.ndarray:
    """Real -> two's-complement fixed point, returned as unsigned words."""
    if frac_bits is None:
        frac_bits = nbits - 2
    scaled = np.round(np.asarray(x, dtype=np.float64) * (1 << frac_bits)).astype(np.int64)
    lo, hi = -(1 << (nbits - 1)), (1 << (nbits - 1)) - 1
    scaled = np.clip(scaled, lo, hi)
    return (scaled & ((1 << nbits) - 1)).astype(np.uint64)


def dequantize_fixed(w: np.ndarray, nbits: int, frac_bits: int | None = None) -> np.ndarray:
    if frac_bits is None:
        frac_bits = nbits - 2
    w = np.asarray(w, dtype=np.uint64).astype(np.int64)
    half = np.int64(1 << (nbits - 1))
    signed = np.where(w >= half, w - (np.int64(1) << np.int64(nbits)), w)
    return signed.astype(np.float64) / (1 << frac_bits)


def float_bits(x: np.ndarray, dtype: str) -> Tuple[np.ndarray, int]:
    """Raw bit patterns of float32/float64 data + word width."""
    if dtype == "float":
        return np.asarray(x, dtype=np.float32).view(np.uint32).astype(np.uint64), 32
    if dtype == "double":
        return np.asarray(x, dtype=np.float64).view(np.uint64), 64
    raise KeyError(dtype)


DATA_TYPES = {
    # name -> (nbits, padded storage bits on a 32/64-bit aligned bus)
    "fixed12": (12, 16),
    "fixed18": (18, 32),
    "fixed24": (24, 32),
    "fixed28": (28, 32),
    "float": (32, 32),
    "double": (64, 64),
}


def words_for(data: np.ndarray, dtype: str) -> Tuple[np.ndarray, int]:
    """Convert real-valued data to codec words for the named paper dtype."""
    if dtype.startswith("fixed"):
        nbits = DATA_TYPES[dtype][0]
        return quantize_fixed(data, nbits), nbits
    return float_bits(data, dtype)
