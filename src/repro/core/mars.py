"""MARS extraction: Maximal Atomic irRedundant Sets (paper §3.1, Ferry'23).

For a tiled single-assignment program, the flow-out data of a tile is
partitioned into groups of points that share the *same set of consumer
tiles*.  Each group is a MARS:

* **atomic** — every point in a group is read by exactly the same consumer
  tiles, so if a tile needs one point of the group it needs all of them;
* **irredundant** — the groups partition the flow-out set, so every value is
  stored exactly once;
* **maximal** — merging two distinct groups would break atomicity.

Full tiles of a uniform stencil are translation-invariant, so the analysis is
performed once on a representative interior tile; consumer tiles are recorded
as *relative* tile offsets.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from fractions import Fraction
from typing import Dict, FrozenSet, List, Optional, Tuple

import numpy as np

from .stencil import StencilSpec

TileOffset = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Mars:
    """One maximal atomic irredundant set of a representative tile."""

    #: relative offsets of the tiles consuming this MARS (never empty)
    consumers: Tuple[TileOffset, ...]
    #: points of the MARS, original iteration-space coords, lexicographic order
    points: np.ndarray  # [n_points, ndim] int64

    @property
    def size(self) -> int:
        return int(self.points.shape[0])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Mars(consumers={self.consumers}, n={self.size})"


@dataclasses.dataclass(frozen=True)
class MarsAnalysis:
    """Result of the MARS analysis on a representative full tile."""

    spec: StencilSpec
    #: output MARS of the tile (flow-out partition)
    out_mars: Tuple[Mars, ...]
    #: for each producer-tile offset, the indices (into that producer's
    #: out_mars — identical to ours by uniformity) consumed by this tile
    consumed: Dict[TileOffset, Tuple[int, ...]]
    #: tile volume (number of iteration points per full tile)
    tile_points: int

    @property
    def n_out(self) -> int:
        return len(self.out_mars)

    @property
    def n_in(self) -> int:
        """Number of input MARS = sum over producers of consumed sets."""
        return sum(len(v) for v in self.consumed.values())

    def out_sizes(self) -> List[int]:
        return [m.size for m in self.out_mars]


def _enumerate_tile_points(spec: StencilSpec, tile_index: np.ndarray) -> np.ndarray:
    """All integer iteration points p with tile_of(p) == tile_index.

    Enumerates the skewed-space box and keeps integral preimages of S^-1.
    """
    S = spec.skew_matrix
    ts = np.asarray(spec.tile_sizes, dtype=np.int64)
    lo = tile_index * ts
    ranges = [range(int(lo[d]), int(lo[d] + ts[d])) for d in range(spec.ndim)]
    ys = np.array(list(itertools.product(*ranges)), dtype=np.int64)
    # invert: p = S^-1 y ; use exact rational inverse
    Sf = [[Fraction(int(S[i, j])) for j in range(spec.ndim)] for i in range(spec.ndim)]
    # Gaussian elimination to get inverse as Fractions
    n = spec.ndim
    aug = [row[:] + [Fraction(int(i == r)) for i in range(n)] for r, row in enumerate(Sf)]
    for col in range(n):
        piv = next(r for r in range(col, n) if aug[r][col] != 0)
        aug[col], aug[piv] = aug[piv], aug[col]
        pv = aug[col][col]
        aug[col] = [x / pv for x in aug[col]]
        for r in range(n):
            if r != col and aug[r][col] != 0:
                f = aug[r][col]
                aug[r] = [a - f * b for a, b in zip(aug[r], aug[col])]
    inv = aug  # rows: [.., identity | inverse]
    num = np.array([[int(inv[i][n + j].numerator) for j in range(n)] for i in range(n)],
                   dtype=np.int64)
    den = np.array([[int(inv[i][n + j].denominator) for j in range(n)] for i in range(n)],
                   dtype=np.int64)
    lcm = int(np.lcm.reduce(den.reshape(-1)))
    scaled = num * (lcm // den)
    prod = ys @ scaled.T  # = lcm * p
    integral = np.all(prod % lcm == 0, axis=1)
    pts = prod[integral] // lcm
    return pts


CANONICAL_TILE_COORD = 64  # deep inside the (unbounded) domain


def analyze(spec: StencilSpec, rep_tile: Tuple[int, ...] | None = None) -> MarsAnalysis:
    """MARS analysis for a representative tile — memoized via translation.

    The analysis is domain-free and uniform stencils are translation
    invariant, so the expensive partition is computed once per spec on a
    canonical tile (:func:`_analyze_canonical`, ``lru_cache``d) and other
    tiles are served by translating the canonical point sets whenever the
    tile offset maps to an integral iteration-space shift (always, for
    unimodular-times-diagonal tilings like the paper's).  Non-integral
    offsets fall back to the direct computation.
    """
    ndim = spec.ndim
    canonical_rep = tuple([CANONICAL_TILE_COORD] * ndim)
    canonical = _analyze_canonical(spec)
    if rep_tile is None or tuple(rep_tile) == canonical_rep:
        return canonical
    dc = np.asarray(rep_tile, dtype=np.int64) - np.asarray(
        canonical_rep, dtype=np.int64)
    shift = _integral_point_shift(spec, dc)
    if shift is not None:
        return _translate_analysis(canonical, shift)
    return _analyze_at(spec, tuple(int(x) for x in rep_tile))


@functools.lru_cache(maxsize=None)
def _analyze_canonical(spec: StencilSpec) -> MarsAnalysis:
    return _analyze_at(spec, tuple([CANONICAL_TILE_COORD] * spec.ndim))


def _integral_point_shift(spec: StencilSpec,
                          dc: np.ndarray) -> Optional[np.ndarray]:
    """Iteration-space translation matching tile offset ``dc``, if integral.

    Tiles are boxes in the skewed basis, so shifting the skewed coords by
    ``dc * tile_sizes`` moves tile ``c0`` onto ``c0 + dc``; the preimage
    ``S^-1 (dc * ts)`` is the iteration-space shift when it is integral.
    """
    S = spec.skew_matrix
    y = dc * np.asarray(spec.tile_sizes, dtype=np.int64)
    x = np.linalg.solve(S.astype(np.float64), y.astype(np.float64))
    xi = np.rint(x).astype(np.int64)
    if np.array_equal(S @ xi, y):
        return xi
    return None


def _translate_analysis(a: MarsAnalysis, shift: np.ndarray) -> MarsAnalysis:
    """Translate every MARS point set by ``shift`` (structure is unchanged)."""
    out = tuple(Mars(consumers=m.consumers, points=m.points + shift)
                for m in a.out_mars)
    return MarsAnalysis(spec=a.spec, out_mars=out, consumed=a.consumed,
                        tile_points=a.tile_points)


def _analyze_at(spec: StencilSpec, rep_tile: Tuple[int, ...]) -> MarsAnalysis:
    """Direct (uncached) MARS analysis of one tile."""
    ndim = spec.ndim
    c0 = np.asarray(rep_tile, dtype=np.int64)
    pts = _enumerate_tile_points(spec, c0)
    if pts.shape[0] == 0:
        raise ValueError(f"empty representative tile for {spec.name}")
    reads = np.asarray(spec.reads, dtype=np.int64)  # [R, ndim]

    # --- flow-out partition (output MARS) ---------------------------------
    # consumers of p: q = p - r for each read offset r
    consumers_of = pts[:, None, :] - reads[None, :, :]          # [n, R, ndim]
    cons_tiles = spec.tile_of(consumers_of.reshape(-1, ndim)).reshape(
        pts.shape[0], reads.shape[0], ndim)
    rel = cons_tiles - c0[None, None, :]
    sig: List[FrozenSet[TileOffset]] = []
    for k in range(pts.shape[0]):
        offs = {tuple(int(x) for x in rel[k, j]) for j in range(reads.shape[0])}
        offs.discard(tuple([0] * ndim))
        sig.append(frozenset(offs))

    groups: Dict[FrozenSet[TileOffset], List[int]] = {}
    for k, s in enumerate(sig):
        if s:  # flow-out only
            groups.setdefault(s, []).append(k)

    def _sig_key(s: FrozenSet[TileOffset]) -> Tuple:
        return tuple(sorted(s))

    out_mars: List[Mars] = []
    for s in sorted(groups.keys(), key=_sig_key):
        idx = groups[s]
        gpts = pts[idx]
        order = np.lexsort(gpts.T[::-1])  # lexicographic by (dim0, dim1, ...)
        out_mars.append(Mars(consumers=tuple(sorted(s)), points=gpts[order]))

    # --- consumed input MARS per producer ---------------------------------
    # values read by the tile but produced elsewhere
    read_pts = pts[:, None, :] + reads[None, :, :]
    read_pts = read_pts.reshape(-1, ndim)
    prod_tiles = spec.tile_of(read_pts)
    rel_prod = prod_tiles - c0[None, :]
    outside = np.any(rel_prod != 0, axis=1)
    ext_pts = read_pts[outside]
    ext_rel = rel_prod[outside]

    # identify, for each external point, which out-MARS of its producer it
    # belongs to.  By uniformity the producer's MARS partition is ours
    # translated by (producer_tile - c0) in *tiled* space; rather than
    # translating point sets, recompute the point's signature in the
    # producer's frame.
    consumed: Dict[TileOffset, set] = {}
    # signature -> out-mars index
    sig_to_idx = {m.consumers: i for i, m in enumerate(out_mars)}
    cons_all = ext_pts[:, None, :] - reads[None, :, :]
    cons_all_tiles = spec.tile_of(cons_all.reshape(-1, ndim)).reshape(
        ext_pts.shape[0], reads.shape[0], ndim)
    own_tiles = spec.tile_of(ext_pts)
    for k in range(ext_pts.shape[0]):
        producer = tuple(int(x) for x in ext_rel[k])
        offs = {
            tuple(int(x) for x in (cons_all_tiles[k, j] - own_tiles[k]))
            for j in range(reads.shape[0])
        }
        offs.discard(tuple([0] * ndim))
        key = tuple(sorted(offs))
        if key not in sig_to_idx:
            raise AssertionError(
                f"{spec.name}: external point has signature {key} absent from "
                "the representative tile's partition — tile not interior?")
        consumed.setdefault(producer, set()).add(sig_to_idx[key])

    consumed_t = {k: tuple(sorted(v)) for k, v in sorted(consumed.items())}
    return MarsAnalysis(
        spec=spec,
        out_mars=tuple(out_mars),
        consumed=consumed_t,
        tile_points=int(pts.shape[0]),
    )


def check_partition(analysis: MarsAnalysis) -> None:
    """Invariant checks: MARS partition the flow-out set (irredundancy)."""
    seen = set()
    for m in analysis.out_mars:
        for p in m.points:
            key = tuple(int(x) for x in p)
            if key in seen:
                raise AssertionError(f"point {key} in two MARS (redundant)")
            seen.add(key)
        if not m.consumers:
            raise AssertionError("MARS with no consumer")
