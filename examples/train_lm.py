"""End-to-end training driver.

Default: a ~100M-param llama-family model for 200 steps on the host devices
(CPU-friendly size: reduce with --small for CI).  Demonstrates the full
production path: config -> sharded train step -> checkpointed fault-tolerant
loop -> resume.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --small --steps 30
"""
import argparse

from repro.configs.base import ModelConfig, RunConfig
from repro.train.loop import LoopConfig, train


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000)


def model_small() -> ModelConfig:
    return ModelConfig(
        name="llama-5m", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    rc = RunConfig(
        seq_len=args.seq or (128 if args.small else 512),
        global_batch=args.batch or (8 if args.small else 16),
        kind="train", remat=False, q_block=128, kv_block=128, lr=6e-4)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                      ckpt_dir=args.ckpt)
    import logging
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    hist = train(cfg, rc, loop, log_every=10)
    print(f"\nfinal loss {hist['loss'][-1]:.4f} "
          f"(from {hist['loss'][0]:.4f}); "
          f"median step {sorted(hist['step_time'])[len(hist['step_time'])//2]:.2f}s")


if __name__ == "__main__":
    main()
