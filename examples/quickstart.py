"""Quickstart: the paper's full pipeline on jacobi-1d in ~40 lines.

MARS extraction -> layout ILP -> compression/packing -> tiled execution ->
I/O-cycle comparison against non-MARS access patterns.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import layout, mars, stencil, transfer
from repro.core.executor import Jacobi1dMarsExecutor

# 1. Analyze the tiled stencil: which data flows between tiles?
spec = stencil.jacobi1d_spec(tile_sizes=(6, 6))
analysis = mars.analyze(spec)
print(f"MARS: {analysis.n_in} in / {analysis.n_out} out per tile "
      f"(paper Table 1: 7 / 4)")

# 2. Solve the layout ILP (Algorithm 1): order MARS to coalesce reads.
lay = layout.layout_for_analysis(analysis)
print(f"layout order {lay.order} -> {lay.read_bursts} read bursts, "
      f"{lay.write_bursts} write burst (paper: 3 / 1)")

# 3. Execute the accelerator model end to end with compressed MARS streams.
n, tsteps = 120, 48
init = np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, n)) + 1.0
ex = Jacobi1dMarsExecutor(spec, n, tsteps, dtype="fixed18")
out = ex.run(init)
ref = stencil.jacobi1d_reference(init, tsteps)[tsteps]
print(f"executor max |err| vs dense reference: {np.abs(out - ref).max():.2e}")
print(f"aggregate compression (padded baseline): "
      f"{ex.stats.uncompressed_bits / ex.stats.compressed_bits:.2f}x")

# 4. Compare I/O cycles across access patterns (paper Fig. 10).
spec_big = stencil.jacobi1d_spec((64, 64))
a_big = mars.analyze(spec_big)
l_big = layout.layout_for_analysis(a_big)
hist = stencil.jacobi1d_reference(
    np.cumsum(np.random.default_rng(1).uniform(-0.01, 0.01, 4000)) + 1.0, 300)
rep = tuple(int(x) for x in spec_big.tile_of(np.array([[150, 2000]]))[0])
model = transfer.TileIOModel(spec_big, a_big, l_big, rep_tile=rep)
print("\nper-tile I/O cycles (fixed18, 64x64 tiles):")
for mode in transfer.MODES:
    io = model.tile_io("fixed18", mode, hist=hist)
    print(f"  {mode:10s} {io.total_cycles:6d} cycles "
          f"({io.read_transactions} read tx)")
