"""The §4 macro-pipeline as a Pallas TPU kernel (interpret-mode demo).

Chunked jacobi-1d: each grid step DMAs one chunk HBM->VMEM, advances it T
time steps, carries the inter-tile MARS (2 columns x T levels) through VMEM
scratch — irredundant inter-tile dataflow, per the paper.

Run:  PYTHONPATH=src python examples/stencil_kernel.py
"""
import numpy as np

import jax.numpy as jnp

from repro.kernels import ops, ref

n, T, W = 1 << 15, 32, 512
x = jnp.asarray(np.cumsum(np.random.default_rng(0).uniform(-0.01, 0.01, n)),
                jnp.float32)

y_kernel = ops.jacobi1d_tiled(x, T, width=W, use_pallas="interpret")
y_ref = ref.jacobi_chunked_ref(x, T)
err = float(jnp.abs(y_kernel - y_ref).max())
print(f"jacobi1d chunked kernel: n={n} T={T} W={W}")
print(f"max |kernel - reference| = {err:.2e}")

halo_reads = (n // W) * 2 * T * 4
print(f"irredundant carry saves {halo_reads / 1e3:.1f} kB of halo re-reads "
      f"per pass vs overlapped tiling "
      f"({100 * halo_reads / (n * 4):.1f}% of the input)")
