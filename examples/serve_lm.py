"""Batched serving with a packed (paper-layout) KV cache.

Generates continuations for a batch of mixed-length prompts twice — bf16
cache vs packed int8 — and reports cache footprint + agreement.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.serve.engine import ServeEngine

cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=768, vocab=4096)

prompts = [[1, 7, 42], [9, 9], [100, 200, 300, 400], [5]]

engines = {}
for bits in (16, 8):
    rc = RunConfig(seq_len=64, global_batch=len(prompts), kind="decode",
                   remat=False, kv_cache_bits=bits)
    eng = ServeEngine(cfg, rc, params=engines.get(16, None) and engines[16].params,
                      seed=0)
    engines[bits] = eng
    out = eng.generate(prompts, max_new=12)
    print(f"kv_cache_bits={bits}: cache={eng.kv_cache_bytes(len(prompts)):,} B")
    for p, o in zip(prompts, out):
        print(f"  prompt {p} -> {o}")

agree = np.mean([
    a == b for a, b in zip(
        sum(engines[16].generate(prompts, max_new=12), []),
        sum(engines[8].generate(prompts, max_new=12), []))])
print(f"\nint8-packed vs bf16 greedy agreement: {agree:.0%} "
      "(quantization may flip rare near-ties)")
